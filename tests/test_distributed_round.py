"""The shard_map distributed Gibbs round (core/distributed.py) on a real
multi-device mesh — run in a subprocess so the forced device count never
leaks into other tests."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import distributed, lda, ps
    from repro.data.synthetic import CorpusConfig, make_topic_corpus

    assert len(jax.devices()) == 8

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=8, vocab_size=128, n_docs=64, doc_len=32, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)

    cfg = lda.LDAConfig(n_topics=8, vocab_size=128, mh_steps=2)
    dcfg = distributed.DistConfig(model="lda", tau=1)
    key = jax.random.PRNGKey(0)
    local, shared = lda.init_state(cfg, tokens, mask, key)

    with mesh:
        round_fn = distributed.make_round_fn(cfg, dcfg, mesh)
        p0 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
        alive = jnp.ones((4,), bool)
        for r in range(8):
            tables, stale = lda.build_alias(cfg, shared)
            local, shared = round_fn(local, shared, tables, stale, tokens,
                                     mask, jax.random.fold_in(key, r), alive)
        p1 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))

    # Convergence across the mesh
    assert p1 < p0 * 0.8, (p0, p1)
    # Shared statistics remain consistent with the summed local assignments
    nwk = lda.count_wk(cfg, tokens, local.z, mask)
    err = float(jnp.abs(nwk - shared.n_wk).max())
    assert err == 0.0, err
    # Failure injection: a dead client contributes nothing, system still OK
    with mesh:
        alive = alive.at[1].set(False)
        tables, stale = lda.build_alias(cfg, shared)
        local2, shared2 = round_fn(local, shared, tables, stale, tokens,
                                   mask, jax.random.fold_in(key, 99), alive)
        p2 = float(lda.perplexity(cfg, shared2, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
    assert np.isfinite(p2) and p2 < p0, (p0, p2)

    # The token-sorted fast path under shard_map: the same registry round
    # with DistConfig(layout="sorted") must run on the mesh and keep the
    # shared statistics consistent with the summed local assignments.
    with mesh:
        round_fn_sorted = distributed.make_round_fn(
            cfg, distributed.DistConfig(model="lda", tau=1,
                                        layout="sorted"), mesh)
        alive = jnp.ones((4,), bool)
        tables, stale = lda.build_alias(cfg, shared)
        local_s, shared_s = round_fn_sorted(local, shared, tables, stale,
                                            tokens, mask,
                                            jax.random.fold_in(key, 400),
                                            alive)
    ps_ = float(lda.perplexity(cfg, shared_s, tokens[:16], mask[:16],
                               jax.random.PRNGKey(5)))
    assert np.isfinite(ps_), ps_
    nwk_s = lda.count_wk(cfg, tokens, local_s.z, mask)
    assert float(jnp.abs(nwk_s - shared_s.n_wk).max()) == 0.0

    # PDP and HDP through the same registry-driven round: the one round
    # implementation serves every family (no per-model adapters).
    from repro.core import family, hdp, pdp, projection

    pcfg = pdp.PDPConfig(n_topics=8, vocab_size=128, mh_steps=2,
                         stirling_n_max=128, concentration=5.0)
    plocal, pshared = pdp.init_state(pcfg, tokens, mask, key)
    alive = jnp.ones((4,), bool)
    with mesh:
        round_fn = distributed.make_round_fn(
            pcfg, distributed.DistConfig(model="pdp", tau=1), mesh)
        for r in range(2):
            tables, stale = pdp.build_alias(pcfg, pshared)
            plocal, pshared = round_fn(plocal, pshared, tables, stale,
                                       tokens, mask,
                                       jax.random.fold_in(key, 200 + r),
                                       alive)
    ppdp = float(pdp.perplexity(pcfg, pshared, tokens[:16], mask[:16],
                                jax.random.PRNGKey(5)))
    assert np.isfinite(ppdp)
    # shared projection held the PDP polytope
    fam = family.get("pdp")
    assert float(fam.count_violations(pshared)) == 0.0

    hcfg = hdp.HDPConfig(n_topics=8, vocab_size=128, b1=2.0, mh_steps=2)
    hlocal, hshared = hdp.init_state(hcfg, tokens, mask, key)
    with mesh:
        round_fn = distributed.make_round_fn(
            hcfg, distributed.DistConfig(model="hdp", tau=1), mesh)
        for r in range(2):
            tables, stale = hdp.build_alias(hcfg, hshared)
            hlocal, hshared = round_fn(hlocal, hshared, tables, stale,
                                       tokens, mask,
                                       jax.random.fold_in(key, 300 + r),
                                       alive)
    phdp = float(hdp.perplexity(hcfg, hshared, tokens[:16], mask[:16],
                                jax.random.PRNGKey(5)))
    assert np.isfinite(phdp)
    # HDP's local table-count polytope (1 <= m_dk <= n_dk) — previously
    # silently dropped by the ad-hoc adapter — is enforced in-round.
    hfam = family.get("hdp")
    lv = float(projection.count_violations(
        {"m_dk": hlocal.m_dk, "n_dk": hlocal.n_dk}, hfam.local_rules))
    assert lv == 0.0, lv
    print("DISTRIBUTED_ROUND_OK", p0, p1, p2, ppdp, phdp)
""")


@pytest.mark.slow
def test_distributed_round_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_ROUND_OK" in proc.stdout
