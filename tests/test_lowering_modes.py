"""Lowering-path regression tests: every sharding mode must lower+compile
a reduced arch on a small forced-device mesh (the 512-device production
sweep is exercised by launch/dryrun.py; this guards the same code path in
CI time)."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs.base import InputShape, reduced
    from repro.configs.registry import ARCHITECTURES
    from repro.launch import specs as specs_lib

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape_train = InputShape("tiny_train", seq_len=64, global_batch=8,
                             kind="train")
    shape_decode = InputShape("tiny_decode", seq_len=64, global_batch=8,
                              kind="decode")

    for arch in ("smollm-360m", "mixtral-8x7b", "rwkv6-3b"):
        cfg = reduced(ARCHITECTURES[arch]).replace(vocab_size=512)
        for mode in ("megatron", "zero_seq", "zero_batch"):
            with mesh:
                spec = specs_lib.make_lowering_spec(cfg, shape_train, mesh,
                                                    mode=mode)
                compiled = specs_lib.lower(spec).compile()
                assert compiled is not None
        with mesh:
            spec = specs_lib.make_lowering_spec(cfg, shape_decode, mesh)
            specs_lib.lower(spec).compile()
        print(f"LOWERED {arch}")
    print("ALL_MODES_OK")
""")


@pytest.mark.slow
def test_all_sharding_modes_lower():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_MODES_OK" in proc.stdout
