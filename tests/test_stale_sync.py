"""Stale-synchronous filtered gradient sync (train/sync.py) — the paper's
PS communication pattern applied to training (beyond-paper transfer)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ps
from repro.train import sync as sync_lib


def test_filter_tree_shapes_preserved():
    grads = {"mat": jnp.ones((32, 8)), "vec": jnp.ones((5,)),
             "stack": jnp.ones((4, 16, 3))}
    spec = ps.FilterSpec(kind="topk", k_rows=4, random_rows=2)
    out = sync_lib.filter_tree(grads, spec, jax.random.PRNGKey(0))
    for k in grads:
        assert out[k].shape == grads[k].shape
    # 1-D leaves pass through dense
    np.testing.assert_array_equal(np.asarray(out["vec"]), 1.0)
    # 2-D+: at most k_rows+random rows survive
    kept = (np.abs(np.asarray(out["mat"])).sum(-1) > 0).sum()
    assert kept <= 6


def test_error_feedback_training_converges_to_dense():
    """With error feedback, filtered sync must reach the same fixed point as
    dense sync on a convex problem (delayed, not biased)."""
    w_true = jnp.asarray([1.0, -2.0, 3.0, 0.5])

    def run(spec: ps.FilterSpec, steps=300):
        w = jnp.zeros((4, 1))
        residual = jnp.zeros_like(w)
        for i in range(steps):
            grad = 2 * (w - w_true[:, None])       # quadratic loss
            acc = residual + grad
            sent = ps.filter_delta(acc, spec, jax.random.fold_in(
                jax.random.PRNGKey(0), i))
            residual = acc - sent
            w = w - 0.05 * sent
        return w[:, 0]

    w_dense = run(ps.FilterSpec())
    w_topk = run(ps.FilterSpec(kind="topk", k_rows=1, random_rows=0))
    np.testing.assert_allclose(np.asarray(w_dense), np.asarray(w_true),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(w_topk), np.asarray(w_true),
                               atol=5e-2)


def test_sync_bytes_estimate_monotone():
    params = {"big": jnp.zeros((1024, 64)), "small": jnp.zeros((8,))}
    dense, filt_a = sync_lib.sync_bytes_estimate(
        params, ps.FilterSpec(kind="topk", k_rows=16, random_rows=0))
    _, filt_b = sync_lib.sync_bytes_estimate(
        params, ps.FilterSpec(kind="topk", k_rows=256, random_rows=0))
    assert filt_a < filt_b < dense
