"""Property tests for parameter projection (paper §5.5, Algorithms 1-3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from tests.hypothesis_compat import given, settings, st

from repro.core import projection, ps


def _random_stats(seed, v=24, k=8):
    key = jax.random.PRNGKey(seed)
    km, ks = jax.random.split(key)
    # Deliberately inconsistent statistics (as relaxed consistency produces).
    m = jax.random.randint(km, (v, k), -3, 20).astype(jnp.float32)
    s = jax.random.randint(ks, (v, k), -3, 25).astype(jnp.float32)
    return {"m_wk": m, "s_wk": s, "m_k": m.sum(0), "s_k": s.sum(0)}


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_projection_satisfies_constraints(seed):
    """After projection every PDP constraint holds (the feasible polytope)."""
    stats = _random_stats(seed)
    out = projection.project(stats, projection.PDP_RULES,
                             projection.PDP_AGGREGATES)
    m, s = out["m_wk"], out["s_wk"]
    assert bool(jnp.all(m >= 0))
    assert bool(jnp.all(s >= 0))
    assert bool(jnp.all(s <= m))
    assert bool(jnp.all(jnp.where(m > 0, s >= 1, s == 0)))
    np.testing.assert_allclose(np.asarray(out["m_k"]), np.asarray(m.sum(0)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["s_k"]), np.asarray(s.sum(0)),
                               rtol=1e-6)
    assert float(projection.count_violations(out, projection.PDP_RULES)) == 0.0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_projection_idempotent(seed):
    """Projecting twice equals projecting once (proximal operator property)."""
    stats = _random_stats(seed)
    once = projection.project(stats, projection.PDP_RULES,
                              projection.PDP_AGGREGATES)
    twice = projection.project(once, projection.PDP_RULES,
                               projection.PDP_AGGREGATES)
    for name in once:
        np.testing.assert_array_equal(np.asarray(once[name]),
                                      np.asarray(twice[name]))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_projection_fixes_feasible_points(seed):
    """A feasible point is left untouched (projection = identity on the set)."""
    key = jax.random.PRNGKey(seed)
    m = jax.random.randint(key, (16, 4), 0, 10).astype(jnp.float32)
    s = jnp.where(m > 0, jnp.maximum(jnp.minimum(m, 1.0 + m // 2), 1.0), 0.0)
    stats = {"m_wk": m, "s_wk": s, "m_k": m.sum(0), "s_k": s.sum(0)}
    out = projection.project(stats, projection.PDP_RULES,
                             projection.PDP_AGGREGATES)
    for name in stats:
        np.testing.assert_array_equal(np.asarray(stats[name]),
                                      np.asarray(out[name]))


def test_on_demand_projection():
    """Algorithm 3: the pull-path filter makes reads safe."""
    on_pull = projection.make_on_demand(projection.PDP_RULES)
    stats = _random_stats(3)
    out = on_pull(stats)
    assert float(projection.count_violations(out, projection.PDP_RULES)) == 0.0


class TestFilters:
    def test_dense_filter_identity(self):
        delta = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
        out = ps.filter_delta(delta, ps.FilterSpec(kind="dense"),
                              jax.random.PRNGKey(1))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(delta))

    def test_threshold_filter(self):
        delta = jnp.zeros((8, 4)).at[2].set(5.0).at[5].set(0.01)
        out = ps.filter_delta(delta, ps.FilterSpec(kind="threshold",
                                                   threshold=1.0),
                              jax.random.PRNGKey(0))
        assert float(jnp.abs(out[2]).sum()) > 0
        assert float(jnp.abs(out[5]).sum()) == 0

    def test_topk_keeps_largest_rows(self):
        delta = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        spec = ps.FilterSpec(kind="topk", k_rows=8, random_rows=0)
        out = ps.filter_delta(delta, spec, jax.random.PRNGKey(1))
        mags = np.abs(np.asarray(delta)).sum(-1)
        top = set(np.argsort(-mags)[:8].tolist())
        kept = set(np.nonzero(np.abs(np.asarray(out)).sum(-1) > 0)[0].tolist())
        assert kept == top
        # kept rows are unmodified
        for r in top:
            np.testing.assert_array_equal(np.asarray(out[r]),
                                          np.asarray(delta[r]))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 16), st.integers(0, 8))
    def test_property_compress_roundtrip_subset(self, seed, k_rows, random_rows):
        """compress→decompress never invents mass: the result equals delta on
        selected rows and zero elsewhere; no row is double-applied."""
        delta = jax.random.normal(jax.random.PRNGKey(seed), (32, 4))
        spec = ps.FilterSpec(kind="topk", k_rows=k_rows, random_rows=random_rows)
        comp = ps.compress_delta(delta, spec, jax.random.PRNGKey(seed + 1))
        dense = ps.decompress_delta(comp, 32, 4)
        d, o = np.asarray(delta), np.asarray(dense)
        for r in range(32):
            row_ok = np.allclose(o[r], d[r], atol=1e-6) or np.allclose(o[r], 0)
            assert row_ok, f"row {r} corrupted (double-applied?)"

    def test_residual_error_feedback(self):
        delta = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
        spec = ps.FilterSpec(kind="topk", k_rows=4, random_rows=0)
        sent = ps.filter_delta(delta, spec, jax.random.PRNGKey(1))
        resid = ps.residual_update(jnp.zeros_like(delta), delta, sent)
        # residual + sent == delta exactly: nothing is ever lost (eventual
        # consistency guarantee).
        np.testing.assert_allclose(np.asarray(resid + sent), np.asarray(delta),
                                   atol=1e-6)
