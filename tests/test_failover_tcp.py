"""The DESIGN.md §13 acceptance scenario, asserted end to end.

A loopback BSP run through the chaos proxy with a connection drop on
the push path, one shard-server process killed at its round barrier and
restarted from its own snapshot, and one worker process killed mid-run
and relaunched with ``--restore`` — must finish with exactly the same
statistics, bit for bit, as the undisturbed in-process run
(``consistency_error() == 0`` in trainer terms: the assembled state is
the reference state).

This is the slowest test in the suite (real processes, two scheduled
kills, two relaunches); everything it composes is also covered by the
fast in-thread tests in test_wire_transport.py / test_chaos.py, so a
failure here means the *composition* broke — kill timing, snapshot
cadence, replay after restart — not a unit.
"""

from __future__ import annotations

import pytest

from repro.core.fault import FaultEvent, FaultPlan
from repro.launch.loopback import _reference_run, launch_failover

N_ROUNDS = 6


@pytest.mark.slow
def test_tcp_kill_and_rejoin_bsp_bitexact(tmp_path):
    plan = FaultPlan.scripted(
        # The first worker connection loses its round-1 push (frame 5)
        # on the wire; idempotent replay absorbs it.
        FaultEvent("conn_drop", client=0, start=5, stop=6, period=1))
    res = launch_failover(
        client_sets=((0,), (1,)), n_rounds=N_ROUNDS,
        kill_server_round=3,          # shard dies once round 3 finalizes
        kill_client=1, kill_client_round=2,   # worker dies after round 2
        chaos_plan=plan, timeout=420.0, workdir=str(tmp_path))

    assert res.ok, [(p.name, p.returncode, p.stderr[-2000:])
                    for p in res.failures()] + [res.diagnostics]
    # Exactly one scheduled restart of each process kind happened.
    assert res.restarts == {"server": 1, "client": 1}
    killed = [p.name for p in res.servers + res.clients if p.expected]
    assert sorted(killed) == ["client1#killed", "server#killed"]
    # The wire-level drop actually fired.
    assert sum(p["actions"]["conn_drop"] for p in res.proxies) == 1

    # The parity bit: every surviving worker's final checksums equal the
    # undisturbed in-process run's — the disturbed distributed state *is*
    # the reference state (consistency error zero).
    finals = [p.result for p in res.clients
              if p.returncode == 0 and p.result]
    assert len(finals) == 2
    ref = _reference_run(N_ROUNDS)
    for r in finals:
        assert r["checksums"] == ref["checksums"]
    assert finals[0]["perplexity"] == pytest.approx(ref["perplexity"])
    # The relaunched worker really resumed mid-run rather than redoing
    # the whole schedule: 2 rounds before the kill + 4 after.
    restored = next(r for r in finals if r["restored"])
    assert restored["rounds_done"] == N_ROUNDS - 2
