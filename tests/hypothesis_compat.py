"""Optional-``hypothesis`` shim for the property-based tests.

The tier-1 container does not ship ``hypothesis`` (see requirements-dev.txt
for the full dev environment).  ``pytest.importorskip`` at module scope would
skip the *whole* module, losing the plain unit tests that live next to the
property tests — so instead this shim exports either the real
``given``/``settings``/``st`` or stand-ins that mark just the decorated
property tests as skipped.  Import from here instead of ``hypothesis``:

    from tests.hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="hypothesis not installed (pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):  # type: ignore[misc]
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_args, **_kwargs):  # type: ignore[misc]
        return lambda fn: fn

    class _Dummy:
        """Stand-in strategy: infinitely callable/chainable so module-scope
        constructions like ``@st.composite`` + ``delta_matrices()`` or
        ``st.floats().map(...)`` survive collection; the decorated tests are
        skipped before any of this is ever drawn from."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    _DUMMY = _Dummy()

    class _Strategies:
        def __getattr__(self, _name):
            return _DUMMY

    st = _Strategies()  # type: ignore[assignment]
