"""The ModelFamily protocol + registry (repro.core.family).

Guards the API-unification contract:

1. registry completeness — every family resolves by name and by config
   type, and declares the full protocol surface;
2. rule provenance — each registered family's rules are *identical* to the
   canonical ``projection.*_RULES`` / ``*_AGGREGATES`` (the regression for
   the old ``_HDPAdapter`` that hand-copied an ad-hoc subset), and the
   shared/local split drops nothing;
3. local projection — HDP's 1 ≤ m_dk ≤ n_dk table-count polytope is
   actually enforced on client state;
4. dense-proposal factorization — shapes and mass-consistency of the
   ``dense_probs`` / ``sparse_prior`` / alias-table hooks for every family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family, hdp, projection
from tests.conftest import make_family_cfg, make_synthetic_corpus

CANONICAL = {
    "lda": (projection.LDA_RULES, projection.LDA_AGGREGATES),
    "pdp": (projection.PDP_RULES, projection.PDP_AGGREGATES),
    "hdp": (projection.HDP_RULES, projection.HDP_AGGREGATES),
}


def _cfg(name):
    return make_family_cfg(name, n_topics=8, vocab_size=64)


def test_registry_names_and_config_resolution():
    assert set(family.names()) >= {"lda", "pdp", "hdp"}
    for name in ("lda", "pdp", "hdp"):
        fam = family.get(name)
        assert fam.name == name
        assert family.family_of(_cfg(name)) is fam
    with pytest.raises(KeyError, match="unknown model family"):
        family.get("nope")
    with pytest.raises(TypeError):
        family.family_of(object())


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_rules_match_projection_canon(name):
    """Regression for the old ad-hoc ``_HDPAdapter`` rules: the registry
    must source each family's rules/aggregates verbatim from
    ``repro.core.projection`` and the shared/local split must cover every
    rule — nothing silently dropped in distributed rounds."""
    fam = family.get(name)
    rules, aggregates = CANONICAL[name]
    assert fam.rules == rules
    assert fam.aggregates == aggregates
    assert set(fam.shared_rules) | set(fam.local_rules) == set(rules), \
        "a projection rule is neither shared nor local — it would be dropped"
    # shared/local operand sets really are disjoint responsibilities
    for r in fam.shared_rules:
        names = {r.a} | ({r.b} if r.b else set())
        assert names <= set(fam.shared_stats)
    for r in fam.local_rules:
        names = {r.a} | ({r.b} if r.b else set())
        assert names <= set(fam.local_stats)


def test_hdp_local_rules_cover_table_polytope():
    """HDP's 1 ≤ m_dk ≤ n_dk constraints (hdp.py docstring) live on local
    state and must be in local_rules."""
    fam = family.get("hdp")
    kinds = {(r.kind, r.a, r.b) for r in fam.local_rules}
    assert ("pos_link", "m_dk", "n_dk") in kinds
    assert ("le", "m_dk", "n_dk") in kinds


def test_hdp_local_project_enforces_polytope():
    fam = family.get("hdp")
    n_dk = jnp.asarray([[3.0, 0.0, 5.0], [1.0, 2.0, 0.0]])
    m_dk = jnp.asarray([[7.0, 2.0, 0.0], [-1.0, 1.0, 4.0]])  # all violated
    local = hdp.LocalState(z=jnp.zeros((2, 4), jnp.int32), n_dk=n_dk,
                           m_dk=m_dk)
    assert float(fam.count_local_violations(local)) > 0
    fixed = fam.local_project(local)
    assert float(fam.count_local_violations(fixed)) == 0.0
    np.testing.assert_array_equal(np.asarray(fixed.n_dk), np.asarray(n_dk))
    m = np.asarray(fixed.m_dk)
    n = np.asarray(n_dk)
    assert (m[n > 0] >= 1).all() and (m[n == 0] == 0).all() \
        and (m <= n).all()


def test_lda_pdp_local_project_identity():
    """Families without local rules pass client state through untouched."""
    tokens, mask, _ = make_synthetic_corpus(4, 64, 8, 12, seed=0)
    for name in ("lda", "pdp"):
        fam = family.get(name)
        assert fam.local_rules == ()
        local, _ = fam.init_state(_cfg(name), tokens, mask,
                                  jax.random.PRNGKey(0))
        out = fam.local_project(local)
        for a, b in zip(jax.tree.leaves(local), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_dense_proposal_factorization_shapes(name):
    """dense_probs is (V, E); alias mass matches its row sums;
    sparse_prior is (E,); doc_sparse_logp/accept_ratio behave generically."""
    fam = family.get(name)
    cfg = _cfg(name)
    tokens, mask, _ = make_synthetic_corpus(4, 64, 12, 10, seed=1)
    _, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    e = fam.n_outcomes(cfg)
    assert e == (2 * cfg.n_topics if name == "pdp" else cfg.n_topics)

    dp = fam.dense_probs(cfg, shared)
    assert dp.shape == (cfg.vocab_size, e)
    tables, stale = fam.build_alias(cfg, shared)
    np.testing.assert_array_equal(np.asarray(stale), np.asarray(dp))
    np.testing.assert_allclose(np.asarray(tables.mass),
                               np.asarray(dp.sum(-1)), rtol=1e-5)
    assert tables.prob.shape == (cfg.vocab_size, e)

    prior = fam.sparse_prior(cfg, shared)
    assert prior.shape == (e,)
    assert bool(jnp.all(prior > 0))
    lm = fam.language_model(cfg, shared)
    assert lm.shape == (cfg.vocab_size, cfg.n_topics)

    doc = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (5, e)))
    out = jax.random.randint(jax.random.PRNGKey(2), (5,), 0, e)
    lp = fam.doc_sparse_logp(cfg, shared, doc, out)
    expect = jnp.log(doc[jnp.arange(5), out] + prior[out] + 1e-30)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(expect), rtol=1e-6)
    # accept_ratio is eq. 7 in log space
    a = fam.accept_ratio(jnp.asarray(1.0), jnp.asarray(0.5),
                         jnp.asarray(0.25), jnp.asarray(0.75))
    assert float(a) == pytest.approx(1.0 - 0.5 + 0.25 - 0.75)


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_family_sweep_and_apply_delta(name):
    """Protocol sweep returns the declared delta dict; apply_delta keeps
    the C2 aggregates consistent with their source matrices."""
    fam = family.get(name)
    cfg = _cfg(name)
    tokens, mask, _ = make_synthetic_corpus(4, 64, 12, 10, seed=2)
    local, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = fam.build_alias(cfg, shared)
    local2, deltas = fam.sweep(cfg, local, shared, tables, stale, tokens,
                               mask, jax.random.PRNGKey(1))
    assert set(deltas) == set(fam.delta_names)
    shared2 = fam.apply_delta(shared, deltas)
    stats = fam.stats_dict(shared2)
    for agg in fam.aggregates:
        if agg.src in stats and agg.out in stats:
            np.testing.assert_allclose(
                np.asarray(stats[agg.out]),
                np.asarray(stats[agg.src].sum(agg.axis)), atol=1e-3)
    # count-conserved stats stay consistent through sweep + apply
    counts = fam.count_stats(cfg, tokens, mask, local2)
    for n in fam.conserved_stats:
        np.testing.assert_array_equal(np.asarray(counts[n]),
                                      np.asarray(stats[n]))
