"""Unit tests for the roofline HLO parsing (no devices needed)."""

from __future__ import annotations

import textwrap

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHITECTURES
from repro.launch import roofline as rl


HLO = textwrap.dedent("""\
    HloModule jit_train_step

    %cond.1 (arg.1: (s32[], f32[8,4])) -> pred[] {
      %p = (s32[], f32[8,4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(32)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %body.1 (arg.2: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
      %p2 = (s32[], f32[8,4]) parameter(0)
      %x = f32[8,4] get-tuple-element(%p2), index=1
      %ag = f32[16,4] all-gather(%x), dimensions={0}
      %rs = f32[8,4] reduce-scatter(%ag), dimensions={0}
      ROOT %t = (s32[], f32[8,4]) tuple(%p2)
    }

    ENTRY %main (a: f32[8,4]) -> f32[8,4] {
      %a = f32[8,4] parameter(0)
      %ar = f32[8,4] all-reduce(%a), to_apply=%sum
      %w = (s32[], f32[8,4]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[8,4] get-tuple-element(%w), index=1
    }
""")


def test_shape_bytes():
    assert rl._shape_bytes("f32[8,4]") == 128
    assert rl._shape_bytes("bf16[2,3,4]") == 48
    assert rl._shape_bytes("(f32[4], s32[2])") == 24
    assert rl._shape_bytes("pred[]") == 1  # scalar -> 1 elem


def test_collective_bytes_loop_correction():
    stats = rl.collective_bytes(HLO)
    # all-reduce at top level: 128 bytes × 1
    assert stats.bytes_by_kind["all-reduce"] == 128
    # all-gather inside the while body: 256 bytes × trip count 32
    assert stats.bytes_by_kind["all-gather"] == 256 * 32
    assert stats.bytes_by_kind["reduce-scatter"] == 128 * 32
    assert stats.loop_corrected
    assert stats.count_by_kind["all-gather"] == 32


def test_analytic_flops_sane():
    """Analytic FLOPs must dominate MODEL_FLOPS (6·N·D) but not absurdly."""
    for arch in ("smollm-360m", "mixtral-8x7b", "rwkv6-3b", "zamba2-2.7b"):
        cfg = ARCHITECTURES[arch]
        shape = INPUT_SHAPES["train_4k"]
        af = rl.analytic_flops(cfg, shape)["flops"]
        mf = rl.model_flops(cfg, shape, "train")
        assert af >= mf, (arch, af, mf)
        assert af < 20 * mf, (arch, af, mf)   # remat+attn ≤ ~2.2x usually


def test_analytic_decode_scales_with_cache():
    cfg = ARCHITECTURES["qwen2-1.5b"]
    s32 = INPUT_SHAPES["decode_32k"]
    f32 = rl.analytic_flops(cfg, s32)
    # attention term is linear in cache length for decode
    assert f32["attn"] > 0
    b32 = rl.analytic_hbm_bytes(cfg, s32, chips=256)
    assert b32 > cfg.param_count() * 2.0 / 256   # weights + kv cache
