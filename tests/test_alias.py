"""Unit + property tests for the Walker alias method (paper §3.1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import alias


def implied_distribution(table: alias.AliasTable) -> np.ndarray:
    """Reconstruct the distribution an alias table encodes: each slot i
    contributes prob[i]/K to outcome i and (1-prob[i])/K to alias[i]."""
    prob = np.asarray(table.prob)
    al = np.asarray(table.alias)
    k = prob.shape[-1]
    flat_p = prob.reshape(-1, k)
    flat_a = al.reshape(-1, k)
    out = np.zeros_like(flat_p)
    for r in range(flat_p.shape[0]):
        for i in range(k):
            out[r, i] += flat_p[r, i] / k
            out[r, flat_a[r, i]] += (1 - flat_p[r, i]) / k
    return out.reshape(prob.shape)


@pytest.mark.parametrize("k", [2, 3, 7, 16, 64, 257])
def test_build_exactness(k):
    """The table must encode exactly the normalized input distribution."""
    p = jax.random.gamma(jax.random.PRNGKey(k), 0.3, (k,)) + 1e-6
    t = alias.build(p)
    imp = implied_distribution(t)
    ref = np.asarray(p / p.sum())
    np.testing.assert_allclose(imp, ref, atol=1e-5)


def test_build_batch_shapes():
    p = jax.random.uniform(jax.random.PRNGKey(0), (4, 5, 16)) + 0.01
    t = alias.build(p)
    assert t.prob.shape == (4, 5, 16)
    assert t.alias.shape == (4, 5, 16)
    assert t.mass.shape == (4, 5)
    np.testing.assert_allclose(np.asarray(t.mass), np.asarray(p.sum(-1)),
                               rtol=1e-5)


def test_degenerate_distributions():
    """Point masses and zero rows must not produce NaN tables."""
    k = 8
    point = jnp.zeros((k,)).at[3].set(5.0)
    t = alias.build(point)
    imp = implied_distribution(t)
    assert imp[3] == pytest.approx(1.0, abs=1e-6)
    zero = jnp.zeros((k,))
    t0 = alias.build(zero)  # falls back to uniform
    imp0 = implied_distribution(t0)
    np.testing.assert_allclose(imp0, np.full(k, 1 / k), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 100), st.integers(0, 2**31 - 1))
def test_property_mass_conservation(k, seed):
    """Property: for any distribution, the implied table distribution equals
    the input (total mass preserved slotwise) and prob entries are in [0,1]."""
    p = jax.random.gamma(jax.random.PRNGKey(seed), 0.5, (k,)) + 1e-5
    t = alias.build(p)
    assert bool(jnp.all(t.prob >= -1e-6)) and bool(jnp.all(t.prob <= 1 + 1e-6))
    assert bool(jnp.all((t.alias >= 0) & (t.alias < k)))
    imp = implied_distribution(t)
    np.testing.assert_allclose(imp, np.asarray(p / p.sum()), atol=2e-5)


def test_sample_rows_statistics():
    """Empirical sampling distribution matches the table's distribution."""
    key = jax.random.PRNGKey(0)
    p = jax.random.gamma(key, 0.5, (5, 32)) + 1e-3
    t = alias.build(p)
    rows = jnp.repeat(jnp.arange(5), 20000)
    s = np.asarray(alias.sample_rows(t, rows, jax.random.PRNGKey(1))).reshape(5, -1)
    for r in range(5):
        emp = np.bincount(s[r], minlength=32) / s.shape[1]
        ref = np.asarray(p[r] / p[r].sum())
        assert 0.5 * np.abs(emp - ref).sum() < 0.03
