"""Integration tests: LDA / PDP / HDP samplers converge and stay consistent."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hdp, lda, pdp, projection


KEY = jax.random.PRNGKey(0)


class TestLDA:
    @pytest.mark.parametrize("method", ["exact", "mhw"])
    def test_convergence_and_consistency(self, small_corpus, method):
        tokens, mask, _ = small_corpus
        cfg = lda.LDAConfig(n_topics=6, vocab_size=120, alpha=0.1, beta=0.01,
                            mh_steps=2)
        local, shared = lda.init_state(cfg, tokens, mask, KEY)
        p0 = lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        for it in range(25):
            tables, stale = lda.build_alias(cfg, shared)
            local, dwk, dk = lda.sweep(cfg, local, shared, tables, stale,
                                       tokens, mask, jax.random.fold_in(KEY, it),
                                       method=method)
            shared = lda.apply_delta(shared, dwk, dk)
        p1 = lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        # Counts remain exactly consistent with assignments (invariant).
        nwk = lda.count_wk(cfg, tokens, local.z, mask)
        assert float(jnp.abs(nwk - shared.n_wk).max()) == 0.0
        assert float(jnp.abs(shared.n_wk.sum(0) - shared.n_k).max()) < 1e-3
        assert float(p1) < float(p0) * 0.7

    def test_mhw_matches_exact_quality(self, small_corpus):
        """Paper claim: AliasLDA reaches perplexity ≥ as good as the sparse
        sampler (Fig 4) — check final perplexities are within 15%."""
        tokens, mask, _ = small_corpus
        cfg = lda.LDAConfig(n_topics=6, vocab_size=120, mh_steps=4)
        finals = {}
        for method in ["exact", "mhw"]:
            local, shared = lda.init_state(cfg, tokens, mask, KEY)
            for it in range(30):
                tables, stale = lda.build_alias(cfg, shared)
                local, dwk, dk = lda.sweep(
                    cfg, local, shared, tables, stale, tokens, mask,
                    jax.random.fold_in(KEY, it), method=method)
                shared = lda.apply_delta(shared, dwk, dk)
            finals[method] = float(lda.perplexity(
                cfg, shared, tokens[:16], mask[:16], jax.random.PRNGKey(5)))
        assert finals["mhw"] < finals["exact"] * 1.15

    def test_topics_per_word_decreases(self, small_corpus):
        """Paper Fig 4 middle panel: topics/word concentrates over time."""
        tokens, mask, _ = small_corpus
        cfg = lda.LDAConfig(n_topics=6, vocab_size=120)
        local, shared = lda.init_state(cfg, tokens, mask, KEY)
        t0 = float(lda.topics_per_word(shared))
        for it in range(20):
            tables, stale = lda.build_alias(cfg, shared)
            local, dwk, dk = lda.sweep(cfg, local, shared, tables, stale,
                                       tokens, mask, jax.random.fold_in(KEY, it))
            shared = lda.apply_delta(shared, dwk, dk)
        t1 = float(lda.topics_per_word(shared))
        assert t1 < t0


class TestPDP:
    @pytest.mark.parametrize("method", ["exact", "mhw"])
    def test_convergence_with_projection(self, small_corpus, method):
        tokens, mask, _ = small_corpus
        cfg = pdp.PDPConfig(n_topics=6, vocab_size=120, alpha=0.1,
                            discount=0.1, concentration=5.0, mh_steps=4,
                            stirling_n_max=256)
        local, shared = pdp.init_state(cfg, tokens, mask, KEY)
        p0 = pdp.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        for it in range(30):
            tables, stale = pdp.build_alias(cfg, shared)
            local, dm, ds = pdp.sweep(cfg, local, shared, tables, stale,
                                      tokens, mask, jax.random.fold_in(KEY, it),
                                      method=method)
            shared = pdp.apply_delta(shared, dm, ds)
            stats = projection.project(
                {"m_wk": shared.m_wk, "s_wk": shared.s_wk,
                 "m_k": shared.m_k, "s_k": shared.s_k},
                projection.PDP_RULES, projection.PDP_AGGREGATES)
            shared = pdp.SharedStats(**stats)
        p1 = pdp.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        assert float(p1) < float(p0) * 0.65
        # Constraints hold after projection.
        viol = projection.count_violations(
            {"m_wk": shared.m_wk, "s_wk": shared.s_wk}, projection.PDP_RULES)
        assert float(viol) == 0.0


class TestHDP:
    @pytest.mark.parametrize("method", ["exact", "mhw"])
    def test_convergence(self, small_corpus, method):
        tokens, mask, _ = small_corpus
        cfg = hdp.HDPConfig(n_topics=12, vocab_size=120, b0=1.0, b1=2.0,
                            mh_steps=4)
        local, shared = hdp.init_state(cfg, tokens, mask, KEY)
        p0 = hdp.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        for it in range(30):
            tables, stale = hdp.build_alias(cfg, shared)
            local, dwk, dk = hdp.sweep(cfg, local, shared, tables, stale,
                                       tokens, mask, jax.random.fold_in(KEY, it),
                                       method=method)
            shared = hdp.apply_delta(cfg, shared, dwk, dk)
            local, m_k = hdp.resample_tables(cfg, local, shared,
                                             jax.random.fold_in(KEY, 1000 + it))
            theta0 = hdp.resample_theta0(cfg, m_k, jax.random.fold_in(KEY, 2000 + it))
            shared = hdp.apply_delta(cfg, shared, jnp.zeros_like(dwk),
                                     jnp.zeros_like(dk), m_k, theta0)
        p1 = hdp.perplexity(cfg, shared, tokens[:16], mask[:16],
                            jax.random.PRNGKey(5))
        assert float(p1) < float(p0) * 0.65

    def test_crt_table_constraints(self, small_corpus):
        """1 ≤ m_dk ≤ n_dk whenever n_dk > 0; m_dk = 0 otherwise."""
        tokens, mask, _ = small_corpus
        cfg = hdp.HDPConfig(n_topics=12, vocab_size=120)
        local, shared = hdp.init_state(cfg, tokens, mask, KEY)
        local, m_k = hdp.resample_tables(cfg, local, shared, KEY)
        n, m = local.n_dk, local.m_dk
        assert bool(jnp.all(m <= n))
        assert bool(jnp.all(jnp.where(n > 0, m >= 1, m == 0)))


class TestStirling:
    def test_known_values(self):
        """a=0 gives unsigned Stirling numbers of the first kind."""
        import math
        from repro.core import stirling
        t = stirling.log_stirling_table(8, 0.0)
        assert math.exp(t[4, 2]) == pytest.approx(11.0, rel=1e-9)
        assert math.exp(t[5, 3]) == pytest.approx(35.0, rel=1e-9)
        assert math.exp(t[3, 3]) == pytest.approx(1.0, rel=1e-9)

    def test_recurrence_holds(self):
        from repro.core import stirling
        a = 0.3
        t = np.asarray(stirling.log_stirling_table(32, a), dtype=np.float64)
        for n in range(2, 31):
            for m in range(1, n):
                lhs = np.exp(t[n + 1, m])
                rhs = np.exp(t[n, m - 1]) + (n - m * a) * np.exp(t[n, m])
                assert lhs == pytest.approx(rhs, rel=1e-6)
