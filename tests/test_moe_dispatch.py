"""MoE dispatch correctness: grouped/vmapped and shard_map all-to-all paths
must agree with the dense oracle (no-drop capacity) and with each other."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.models import moe as moe_mod


@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduced(ARCHITECTURES["mixtral-8x7b"]).replace(
        capacity_factor=8.0)     # no drops → dense oracle comparable
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_sorted_dispatch_matches_dense_oracle(moe_setup):
    cfg, p, x = moe_setup
    out, aux = moe_mod.moe_block(cfg, p, x)
    ref = moe_mod.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2, rtol=5e-2)
    assert float(aux) > 0.0


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_dispatch_matches_global(moe_setup, groups):
    """Grouped-local dispatch == global dispatch when nothing is dropped
    (per-group capacity at cf=8 is ample)."""
    cfg, p, x = moe_setup
    out_global, _ = moe_mod.moe_block(cfg, p, x)
    out_grouped, _ = moe_mod.moe_block(
        cfg.replace(moe_groups=groups), p, x)
    np.testing.assert_allclose(np.asarray(out_grouped, np.float32),
                               np.asarray(out_global, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_capacity_drops_are_per_group(moe_setup):
    """With a tight capacity, grouped dispatch drops per group — outputs
    stay finite and bounded."""
    cfg, p, x = moe_setup
    tight = cfg.replace(capacity_factor=0.5, moe_groups=4)
    out, aux = moe_mod.moe_block(tight, p, x)
    assert bool(jnp.isfinite(out).all())
    assert bool(jnp.isfinite(aux))


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import reduced
    from repro.configs.registry import ARCHITECTURES
    from repro.models import layers, moe as moe_mod

    # 4 experts over model axis of size 4 (divides); mesh (2, 4) = 8 devices
    cfg = reduced(ARCHITECTURES["mixtral-8x7b"]).replace(
        capacity_factor=8.0, moe_groups=8)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32)

    # Reference: single-device global dispatch (no mesh, no act spec).
    ref, _ = moe_mod.moe_block(cfg.replace(moe_groups=0), p, x)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    layers.set_activation_spec(P(("data", "model"), None, None), None, mesh)
    try:
        with mesh:
            fn = jax.jit(lambda p, x: moe_mod.moe_block(cfg, p, x)[0])
            out = fn(p, x)
    finally:
        layers.set_activation_spec(None)
    got = np.asarray(out, np.float32)
    refn = np.asarray(ref, np.float32)
    err = np.abs(got - refn).max()
    assert err < 5e-2, f"shard_map MoE diverges from reference: {err}"
    print("SHARD_MAP_MOE_OK", err)
""")


@pytest.mark.slow
def test_shard_map_a2a_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARD_MAP_MOE_OK" in proc.stdout
