"""Compiled sync rounds (engine.round): compile stability, parity with the
Python reference loop, and incremental alias maintenance.

The contracts of the fused round engine (DESIGN.md §8):

1. one trace per (family, layout) — per-round cadence (round index, failure
   mask, projection flag) enters traced, so steady-state rounds never
   retrace;
2. the compiled round reproduces the PR-2 Python reference loop bit-exactly
   on the count statistics (identical RNG keying, integer-valued fp32);
3. delta-driven incremental alias rebuilds preserve the sufficient-
   statistics conservation contract exactly and stay perplexity-par with
   full per-round rebuilds (the alias table is only an MH proposal — extra
   staleness may slow mixing but must not bias the counts);
4. a partial rebuild over every row is bit-identical to a full rebuild
   (the gather → fused build kernel → scatter path vs. the dense path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family as family_mod
from repro.core import ps
from repro.core.fault import FaultPlan
from repro.engine import Trainer, TrainerConfig
from tests.conftest import make_family_cfg, make_synthetic_corpus

VOCAB = 64


def _cfg(name, k=4):
    return make_family_cfg(name, n_topics=k, vocab_size=VOCAB)


@pytest.fixture(scope="module")
def corpus():
    return make_synthetic_corpus(n_topics=4, vocab=VOCAB, n_docs=16,
                                 doc_len=12, seed=3)


@pytest.mark.parametrize("layout", ["scan", "sorted"])
@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_compiled_round_traces_once(name, layout, corpus):
    """Trace-counter guard: after the first round compiles, ≥3 further
    rounds (spanning projection cadence and a failure-injection window)
    must not retrace the round function."""
    tokens, mask, _ = corpus
    trainer = Trainer(_cfg(name), tokens, mask, config=TrainerConfig(
        layout=layout, n_clients=2, tau=2, project_every=2,
        fault_plan=FaultPlan.crash(1, 2, 3)))
    trainer.step()
    assert trainer.round_traces >= 1
    traced_once = trainer.round_traces
    for _ in range(3):
        trainer.step()
    trainer._sync()
    assert trainer.round_traces == traced_once
    assert trainer.consistency_error() == 0.0


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_compiled_round_matches_python_loop(name, corpus):
    """The compiled round and the PR-2 reference loop share RNG keying and
    op order, so the integer count statistics must match bit-exactly (and
    the remaining shared stats to float tolerance)."""
    tokens, mask, _ = corpus
    trainers = {
        compiled: Trainer(_cfg(name), tokens, mask, config=TrainerConfig(
            n_clients=2, tau=2, compiled=compiled,
            fault_plan=FaultPlan.crash(0, 1, 2)))
        for compiled in (True, False)}
    for _ in range(3):
        for t in trainers.values():
            t.step()
    trainers[True]._sync()
    fam = trainers[True].family
    stats = {c: fam.stats_dict(t.shared) for c, t in trainers.items()}
    for n in fam.conserved_stats:
        np.testing.assert_array_equal(stats[True][n], stats[False][n],
                                      err_msg=n)
    for n in stats[True]:
        np.testing.assert_allclose(stats[True][n], stats[False][n],
                                   rtol=1e-6, err_msg=n)
    for t in trainers.values():
        assert t.consistency_error() == 0.0


def test_compiled_round_matches_python_loop_with_filter(corpus):
    """Same parity contract under a top-k communication filter with
    error-feedback residuals (both paths route through the shared
    filter_push, with identical keying)."""
    tokens, mask, _ = corpus
    spec = ps.FilterSpec(kind="topk", k_rows=8, random_rows=4)
    trainers = {
        compiled: Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
            n_clients=2, filter=spec, compiled=compiled))
        for compiled in (True, False)}
    for _ in range(3):
        for t in trainers.values():
            t.step()
    trainers[True]._sync()
    np.testing.assert_array_equal(trainers[True].shared.n_wk,
                                  trainers[False].shared.n_wk)
    for c in range(2):
        np.testing.assert_array_equal(
            trainers[True].residuals[c]["n_wk"],
            trainers[False].residuals[c]["n_wk"])


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_incremental_alias_conserves_and_stays_perplexity_par(name, corpus):
    """Incremental (delta-driven) alias rebuilds keep the exact count-
    conservation contract and stay within 2% seed-averaged perplexity of
    per-round full rebuilds — the table is an MH proposal, so partial
    staleness must not bias the chain."""
    tokens, mask, _ = corpus
    ppl = {}
    for mode in ("full", "incremental"):
        kw = (dict(alias_rebuild_threshold=0.0, alias_rebuild_rows=32,
                   alias_full_rebuild_every=100)
              if mode == "incremental" else {})
        ppls = []
        for seed in (0, 1, 2, 3, 4):
            t = Trainer(_cfg(name), tokens, mask,
                        config=TrainerConfig(n_clients=2, **kw),
                        key=jax.random.PRNGKey(seed))
            for _ in range(5):
                t.step()
            t._sync()
            assert t.consistency_error() == 0.0
            ppls.append(t.perplexity())
        ppl[mode] = sum(ppls) / len(ppls)
    rel = abs(ppl["incremental"] - ppl["full"]) / ppl["full"]
    assert rel < 0.02, ppl


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_partial_rebuild_all_rows_equals_full_build(name, corpus):
    """rebuild_alias_rows over every row == build_alias, bit-for-bit: the
    gather → fused build-from-stats kernel → scatter path and the dense
    path must agree exactly (same op order by construction)."""
    tokens, mask, _ = corpus
    fam = family_mod.get(name)
    cfg = _cfg(name)
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    loc, sh = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = fam.build_alias(cfg, sh)
    _, d = fam.sweep(cfg, loc, sh, tables, stale, tokens, mask,
                     jax.random.PRNGKey(1))
    sh = fam.apply_delta(sh, d)

    t_full, s_full = fam.build_alias(cfg, sh)
    rows = jnp.arange(cfg.vocab_size, dtype=jnp.int32)
    t_inc, s_inc = fam.rebuild_alias_rows(
        cfg, sh, tables, stale, rows, jnp.ones_like(rows, bool))
    np.testing.assert_array_equal(t_full.prob, t_inc.prob)
    np.testing.assert_array_equal(t_full.alias, t_inc.alias)
    np.testing.assert_array_equal(t_full.mass, t_inc.mass)
    np.testing.assert_array_equal(s_full, s_inc)

    # Sub-selection with a validity mask: invalid rows keep their resident
    # (stale) entries, valid rows get the fresh build.
    sub = jnp.array([3, 9, 11, 40], jnp.int32)
    valid = jnp.array([True, False, True, False])
    t_sub, s_sub = fam.rebuild_alias_rows(cfg, sh, tables, stale, sub, valid)
    np.testing.assert_array_equal(t_sub.prob[3], t_full.prob[3])
    np.testing.assert_array_equal(t_sub.prob[9], tables.prob[9])
    np.testing.assert_array_equal(s_sub[11], s_full[11])
    np.testing.assert_array_equal(s_sub[40], stale[40])


def test_incremental_requires_compiled(corpus):
    tokens, mask, _ = corpus
    with pytest.raises(ValueError, match="compiled"):
        Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
            compiled=False, alias_rebuild_threshold=0.0))


def test_tokens_per_s_nan_before_eval_segments():
    """Before any eval segment is timed there is no throughput number:
    tokens_per_s must be NaN (loud in downstream logs/means), never a
    silent 0.0 a benchmark script could record as a measurement."""
    import math

    from repro.engine import RunResult
    assert math.isnan(RunResult(tokens=1000).tokens_per_s)
    r = RunResult(tokens=1000, iter_times=[0.5])
    assert r.tokens_per_s == pytest.approx(2000.0)
