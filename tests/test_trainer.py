"""engine.Trainer: the unified driver over the ModelFamily registry.

The acceptance contract of the API redesign:

1. every family runs through both layouts with bit-exact
   sufficient-statistics conservation (single-client AND multi-client
   dense sync — integer-valued fp32 counts are exact);
2. multi-client bounded-staleness rounds (tau > 1) are perplexity-matched
   between the sorted fast path and the scan oracle;
3. the Trainer lifecycle knobs (alias cadence, filters + error feedback,
   failure injection, projection cadence) work for any family.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import ps
from repro.core.fault import FaultPlan
from repro.engine import Trainer, TrainerConfig
from tests.conftest import make_family_cfg, make_synthetic_corpus

VOCAB = 96


def _cfg(name, k=8):
    return make_family_cfg(name, n_topics=k, vocab_size=VOCAB)


@pytest.fixture(scope="module")
def corpus():
    return make_synthetic_corpus(n_topics=6, vocab=VOCAB, n_docs=48,
                                 doc_len=24, seed=7)


@pytest.mark.parametrize("layout", ["scan", "sorted"])
@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_trainer_all_families_both_layouts(name, layout, corpus):
    """Every family × layout: rounds run, perplexity improves from the
    first eval to the last, and the maintained shared statistics equal the
    statistics recomputed from the assignments bit-exactly."""
    tokens, mask, _ = corpus
    trainer = Trainer(_cfg(name), tokens, mask, config=TrainerConfig(
        layout=layout, n_clients=2, tau=1))
    res = trainer.run(4, eval_every=3, eval_docs=24)
    assert all(np.isfinite(res.perplexities))
    assert res.perplexities[-1] < res.perplexities[0]
    assert trainer.consistency_error() == 0.0
    assert res.violations[-1] == 0.0


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_trainer_sorted_vs_scan_multiclient_tau2(name, corpus):
    """Distributed-round parity under the new API: multi-client runs with
    tau=2 local sweeps (bounded staleness) reach matching perplexity in
    either layout, and both conserve the sufficient statistics exactly."""
    tokens, mask, _ = corpus
    finals = {}
    for layout in ("scan", "sorted"):
        ppls = []
        for seed in (0, 1):
            trainer = Trainer(_cfg(name), tokens, mask,
                              config=TrainerConfig(layout=layout,
                                                   n_clients=2, tau=2),
                              key=jax.random.PRNGKey(seed))
            res = trainer.run(4, eval_every=10, eval_docs=24)
            assert trainer.consistency_error() == 0.0
            ppls.append(res.perplexities[-1])
        finals[layout] = sum(ppls) / len(ppls)
    rel = abs(finals["sorted"] - finals["scan"]) / finals["scan"]
    assert rel < 0.08, finals


def test_trainer_alias_cadence_and_projection_off(corpus):
    """alias_refresh_every > 1 reuses stale tables between rounds (the l/n
    rule of §3.3) and project_every=0 disables projection.  Rebuilds are
    observed through the Trainer's build counter (the table buffers
    themselves now ride through the compiled round's donated server
    state, so object identity no longer tracks reuse)."""
    tokens, mask, _ = corpus
    trainer = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
        n_clients=2, alias_refresh_every=3, project_every=0))
    trainer.step()
    assert trainer.alias_builds == 1        # round 0: built
    trainer.step()
    trainer.step()
    assert trainer.alias_builds == 1        # rounds 1, 2: reused
    trainer.step()
    assert trainer.alias_builds == 2        # round 3: rebuilt
    trainer._sync()
    assert trainer.consistency_error() == 0.0


def test_trainer_filter_with_error_feedback_converges(corpus):
    """A top-k communication filter with error-feedback residuals keeps the
    run finite and converging (mass withheld is carried, never dropped)."""
    tokens, mask, _ = corpus
    spec = ps.FilterSpec(kind="topk", k_rows=VOCAB // 8,
                         random_rows=VOCAB // 16)
    trainer = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
        n_clients=4, filter=spec))
    res = trainer.run(6, eval_every=5, eval_docs=24)
    assert all(np.isfinite(res.perplexities))
    assert res.perplexities[-1] < res.perplexities[0]


def test_trainer_failure_injection(corpus):
    """A client failing for a window of rounds (§5.4) must not derail the
    run: perplexity stays finite and the system keeps converging."""
    tokens, mask, _ = corpus
    trainer = Trainer(_cfg("hdp"), tokens, mask, config=TrainerConfig(
        n_clients=4, fault_plan=FaultPlan.crash(1, 1, 3)))
    res = trainer.run(5, eval_every=4, eval_docs=24)
    assert all(np.isfinite(res.perplexities))
    assert res.perplexities[-1] < res.perplexities[0]


def test_trainer_hdp_local_polytope_maintained(corpus):
    """The HDP table-count constraints (1 ≤ m_dk ≤ n_dk when n_dk > 0,
    m_dk = 0 otherwise) hold on every client after each round — the
    regression for the constraints the old adapter silently dropped."""
    tokens, mask, _ = corpus
    trainer = Trainer(_cfg("hdp"), tokens, mask,
                      config=TrainerConfig(n_clients=2, tau=2))
    for _ in range(3):
        trainer.step()
        for loc in trainer.locals_:
            assert float(trainer.family.count_local_violations(loc)) == 0.0


def test_trainer_rejects_bad_config(corpus):
    tokens, mask, _ = corpus
    with pytest.raises(ValueError, match="layout"):
        Trainer(_cfg("lda"), tokens, mask,
                config=TrainerConfig(layout="diagonal"))
    with pytest.raises(ValueError, match="sorted"):
        Trainer(_cfg("lda"), tokens, mask,
                config=TrainerConfig(layout="sorted", method="exact"))
    with pytest.raises(TypeError):
        Trainer(object(), tokens, mask)