"""Property tests for the parameter-server layer: communication filters,
compression, error feedback (paper §5.3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.core import ps

KEY = jax.random.PRNGKey(0)


@st.composite
def delta_matrices(draw):
    v = draw(st.integers(4, 40))
    k = draw(st.integers(2, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # Sparse-ish integer deltas like real count updates (pos & neg).
    dense = rng.integers(-3, 4, size=(v, k)).astype(np.float32)
    mask = rng.random((v, k)) < 0.3
    return jnp.asarray(dense * mask)


@given(delta_matrices(), st.integers(1, 10), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_compress_decompress_subset_of_delta(delta, k_rows, random_rows):
    """Decompressed delta only contains rows of the original, each at most
    once (no double-apply from duplicated indices)."""
    spec = ps.FilterSpec(kind="topk", k_rows=k_rows, random_rows=random_rows)
    comp = ps.compress_delta(delta, spec, KEY)
    dense = ps.decompress_delta(comp, delta.shape[0], delta.shape[1])
    # every row of `dense` equals the original row or zero
    orig = np.asarray(delta)
    got = np.asarray(dense)
    for r in range(orig.shape[0]):
        ok = np.allclose(got[r], orig[r]) or np.allclose(got[r], 0.0)
        assert ok, f"row {r} corrupted: {got[r]} vs {orig[r]}"


@given(delta_matrices())
@settings(max_examples=25, deadline=None)
def test_topk_keeps_largest_rows(delta):
    """The magnitude-priority rule: every kept row's L1 mass ≥ any dropped
    row's (modulo the uniformly-sampled anti-starvation rows)."""
    spec = ps.FilterSpec(kind="topk", k_rows=3, random_rows=0)
    filt = ps.filter_delta(delta, spec, KEY)
    mag = np.abs(np.asarray(delta)).sum(-1)
    kept = np.abs(np.asarray(filt)).sum(-1) > 0
    if kept.sum() == 0:
        return
    min_kept = mag[kept].min()
    dropped = mag[~kept]
    if dropped.size:
        assert min_kept >= dropped.max() - 1e-6


@given(delta_matrices(), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_error_feedback_conserves_mass(delta, k_rows):
    """residual + sent == accumulated delta, exactly — the eventual-
    consistency invariant: nothing is ever lost, only delayed."""
    spec = ps.FilterSpec(kind="topk", k_rows=k_rows, random_rows=1)
    residual = jnp.zeros_like(delta)
    total_sent = jnp.zeros_like(delta)
    for i in range(4):
        acc = residual + delta
        sent = ps.filter_delta(acc, spec, jax.random.fold_in(KEY, i))
        residual = acc - sent
        total_sent = total_sent + sent
    np.testing.assert_allclose(
        np.asarray(total_sent + residual), np.asarray(delta) * 4, atol=1e-4)


def test_threshold_filter():
    delta = jnp.asarray([[5.0, 0.0], [0.1, 0.1], [0.0, -3.0]])
    spec = ps.FilterSpec(kind="threshold", threshold=1.0)
    out = np.asarray(ps.filter_delta(delta, spec, KEY))
    assert np.allclose(out[0], [5.0, 0.0])
    assert np.allclose(out[1], 0.0)       # below threshold → withheld
    assert np.allclose(out[2], [0.0, -3.0])


def test_dense_filter_identity():
    delta = jax.random.normal(KEY, (8, 4))
    out = ps.filter_delta(delta, ps.FilterSpec(), KEY)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(delta))


def test_small_leaf_passthrough():
    """k_rows larger than the leaf's rows must keep the whole leaf."""
    delta = jax.random.normal(KEY, (2, 3))
    spec = ps.FilterSpec(kind="topk", k_rows=64, random_rows=16)
    out = ps.filter_delta(delta, spec, KEY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(delta), atol=1e-6)


# ---------------------------------------------------------------------------
# changed_rows (the incremental-alias selection behind the server's push
# path) — edge cases
# ---------------------------------------------------------------------------

def test_changed_rows_all_zero_delta_selects_nothing():
    """threshold=0.0 with an all-zero delta: the fixed-size top-k still
    returns k indices (shapes are static under jit), but the validity
    mask must reject every one — ``mass > threshold`` is strict, so a
    zero push never triggers arbitrary rebuilds."""
    mass = jnp.zeros((16,))
    idx, valid = ps.changed_rows(mass, k_rows=4, threshold=0.0)
    assert idx.shape == (4,)
    assert not bool(np.asarray(valid).any())


def test_changed_rows_k_larger_than_v():
    """k_rows > V clamps to V: every row selectable, none out of range,
    and only rows with mass above threshold valid."""
    mass = jnp.asarray([0.0, 2.0, 0.0, 1.0])
    idx, valid = ps.changed_rows(mass, k_rows=100, threshold=0.0)
    assert idx.shape == (4,)
    idx_np, valid_np = np.asarray(idx), np.asarray(valid)
    assert set(idx_np.tolist()) == {0, 1, 2, 3}
    assert set(idx_np[valid_np].tolist()) == {1, 3}


def test_changed_rows_tie_break_deterministic_under_jit():
    """All-equal masses: the selection is a pure function of the input —
    jitted and eager agree, and repeated jitted calls agree (top_k's
    tie-breaking is deterministic, so the rebuild schedule is
    reproducible)."""
    mass = jnp.ones((12,))
    jitted = jax.jit(ps.changed_rows, static_argnums=(1, 2))
    e_idx, e_valid = ps.changed_rows(mass, 5, 0.5)
    j_idx, j_valid = jitted(mass, 5, 0.5)
    j_idx2, _ = jitted(mass + 0.0, 5, 0.5)
    np.testing.assert_array_equal(np.asarray(e_idx), np.asarray(j_idx))
    np.testing.assert_array_equal(np.asarray(j_idx), np.asarray(j_idx2))
    np.testing.assert_array_equal(np.asarray(e_valid), np.asarray(j_valid))
    # ties broken toward the lower index (jax.lax.top_k contract) — pin
    # it so a silent backend change shows up here, not as alias drift
    np.testing.assert_array_equal(np.asarray(e_idx), [0, 1, 2, 3, 4])
