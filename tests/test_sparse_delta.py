"""Sparse delta exchange — core pytree boundary (DESIGN.md §12).

The contract under test: ``to_sparse_delta`` keeps every row non-zero in
*any* statistic, ``from_sparse_delta`` reconstructs the dense pytree
bit-for-bit, and ``ParameterServer.push_sparse`` therefore lands on the
exact bytes of the dense ``push`` — sparsity is an encoding, never an
approximation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family as family_mod
from repro.core import ps
from repro.core import server as server_mod
from repro.engine import round as round_mod
from tests.conftest import make_family_cfg, make_synthetic_corpus

VOCAB = 64


@pytest.fixture(scope="module")
def corpus():
    return make_synthetic_corpus(n_topics=4, vocab=VOCAB, n_docs=16,
                                 doc_len=12, seed=3)


def _sweep_deltas(name, corpus, key=0):
    """One real sweep's dense deltas (the thing a client would push)."""
    tokens, mask, _ = corpus
    fam = family_mod.get(name)
    cfg = make_family_cfg(name, n_topics=4, vocab_size=VOCAB)
    local, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = fam.build_alias(cfg, shared)
    _, deltas = fam.sweep(cfg, local, shared, tables, stale, tokens, mask,
                          jax.random.PRNGKey(key), method="mhw",
                          layout="scan")
    return fam, cfg, shared, deltas


# ---------------------------------------------------------------------------
# to/from roundtrip
# ---------------------------------------------------------------------------

def test_roundtrip_bitexact_multi_stat():
    rng = np.random.default_rng(0)
    a = np.zeros((10, 4), np.float32)
    b = np.zeros((10, 3), np.float32)
    a[[1, 7]] = rng.normal(size=(2, 4)).astype(np.float32)
    b[[2, 7]] = rng.normal(size=(2, 3)).astype(np.float32)
    sp = ps.to_sparse_delta({"a": a, "b": b})
    # Union of non-zero rows across stats, ascending and unique.
    np.testing.assert_array_equal(np.asarray(sp.rows), [1, 2, 7])
    out = ps.from_sparse_delta(sp, 10)
    np.testing.assert_array_equal(np.asarray(out["a"]), a)
    np.testing.assert_array_equal(np.asarray(out["b"]), b)


def test_roundtrip_zero_delta_is_empty():
    sp = ps.to_sparse_delta({"a": np.zeros((6, 2), np.float32)})
    assert sp.rows.size == 0
    out = ps.from_sparse_delta(sp, 6)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.zeros((6, 2), np.float32))


def test_roundtrip_negative_and_tiny_values_survive():
    a = np.zeros((8, 2), np.float32)
    a[3] = [-1.0, np.float32(1e-30)]   # subnormal-ish values stay exact
    a[5] = [0.0, -0.0]                 # -0.0 row: non-zero by bit, but
    #                                    np.any(v != 0) treats -0.0 == 0 —
    #                                    dropping it is still bit-exact
    #                                    for the *sum* (0 + -0 == 0).
    sp = ps.to_sparse_delta({"a": a})
    np.testing.assert_array_equal(np.asarray(sp.rows), [3])
    out = np.asarray(ps.from_sparse_delta(sp, 8)["a"])
    np.testing.assert_array_equal(out[3], a[3])


@pytest.mark.parametrize("name", ["lda", "pdp"])
def test_roundtrip_real_sweep_deltas(name, corpus):
    _, _, _, deltas = _sweep_deltas(name, corpus)
    dense = {n: np.asarray(v) for n, v in deltas.items()
             if np.asarray(v).shape[:1] == (VOCAB,)}
    sp = ps.to_sparse_delta(dense)
    assert 0 < sp.rows.size < VOCAB  # genuinely sparse on this corpus
    out = ps.from_sparse_delta(sp, VOCAB)
    for n, v in dense.items():
        np.testing.assert_array_equal(np.asarray(out[n]), v, err_msg=n)


# ---------------------------------------------------------------------------
# push_sparse == push on the core server
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["lda", "pdp"])
@pytest.mark.parametrize("n_shards", [1, 3])
def test_push_sparse_bitexact_with_push(name, n_shards, corpus):
    fam, cfg, shared, deltas = _sweep_deltas(name, corpus)
    # Every pushed delta is a (V, ...) row stat: aggregates (n_k, m_k, …)
    # are re-derived by apply_delta (the C2 rule), never shipped.
    assert all(np.asarray(v).shape[:1] == (VOCAB,) for v in deltas.values())

    srv = server_mod.make_server(fam, VOCAB, n_shards=n_shards)
    s_dense = srv.push(srv.init_state(shared, n_clients=1), deltas)
    s_sparse = srv.push_sparse(srv.init_state(shared, n_clients=1),
                               ps.to_sparse_delta(deltas))

    a = fam.stats_dict(srv.snapshot(s_dense))
    b = fam.stats_dict(srv.snapshot(s_sparse))
    for n in a:
        np.testing.assert_array_equal(np.asarray(a[n]), np.asarray(b[n]),
                                      err_msg=n)


def test_filter_push_sparse_matches_filter_push(corpus):
    """The filtered wire path: filter_push then sparsify == the sparse
    helper, and densifying recovers the filtered send exactly."""
    fam, cfg, shared, deltas = _sweep_deltas("lda", corpus)
    spec = ps.FilterSpec()
    key = jax.random.PRNGKey(7)
    sent, residual = round_mod.filter_push(fam, deltas, spec, key)
    sp, residual2 = round_mod.filter_push_sparse(fam, deltas, spec, key)
    if residual is None:
        assert residual2 is None
    else:
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                       np.asarray(y)),
            residual, residual2)
    dense = ps.from_sparse_delta(sp, VOCAB)
    for n, v in sent.items():
        if np.asarray(v).shape[:1] == (VOCAB,):
            np.testing.assert_array_equal(np.asarray(dense[n]),
                                          np.asarray(v), err_msg=n)
