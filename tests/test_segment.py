"""Tests for the token-sorted segmentation layout (repro.data.segment)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import segment


def _toy(d=13, l=17, v=48, seed=0, mask_p=0.8):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, v, size=(d, l)), jnp.int32)
    mask = jnp.asarray(rng.random((d, l)) < mask_p)
    return tokens, mask


@pytest.mark.parametrize("tile_v,tile_b", [(8, 64), (16, 128), (48, 32)])
def test_layout_round_trip(tile_v, tile_b):
    """sort → unsort is the identity on real positions (permutation check)."""
    tokens, mask = _toy(v=48)
    lay = segment.build_layout(tokens, mask, 48, tile_v=tile_v, tile_b=tile_b)
    flat = jnp.arange(tokens.size, dtype=jnp.int32)
    sorted_vals = segment.sort_values(lay, flat, fill=-1)
    # order is a permutation of all flat positions
    assert np.array_equal(np.sort(np.asarray(lay.order)), np.arange(tokens.size))
    back = segment.unsort_values(lay, sorted_vals, jnp.zeros_like(flat))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))


def test_layout_rows_sorted_and_sentinels():
    tokens, mask = _toy(v=48)
    lay = segment.build_layout(tokens, mask, 48, tile_v=8, tile_b=64)
    rows = np.asarray(lay.rows)
    assert (np.diff(rows) >= 0).all(), "sorted stream must be ascending"
    n_real = int(np.asarray(mask).sum())
    assert (rows[:n_real] < 48).all()
    assert (rows[n_real:] == 48).all(), "padding carries the sentinel row"
    # real flags line up with the sentinel split
    np.testing.assert_array_equal(np.asarray(lay.real), rows < 48)
    # docs agree with the permutation
    w = np.asarray(tokens).reshape(-1)
    docs_expect = np.asarray(lay.order) // tokens.shape[1]
    np.testing.assert_array_equal(np.asarray(lay.docs)[:tokens.size], docs_expect)
    # sorted rows equal the permuted (masked) token stream
    key = np.where(np.asarray(mask).reshape(-1), w, 48)
    np.testing.assert_array_equal(rows[:tokens.size], key[np.asarray(lay.order)])


def test_histogram_and_offsets():
    tokens, mask = _toy(v=48)
    lay = segment.build_layout(tokens, mask, 48, tile_v=8, tile_b=64)
    w = np.asarray(tokens).reshape(-1)
    m = np.asarray(mask).reshape(-1)
    expect = np.bincount(w[m] // 8, minlength=6)
    np.testing.assert_array_equal(np.asarray(lay.hist), expect)
    offs = np.asarray(lay.offsets)
    assert offs[0] == 0 and offs[-1] == m.sum()
    np.testing.assert_array_equal(np.diff(offs), expect)
    # CSR contract: draws of tile t occupy sorted positions [offs[t], offs[t+1])
    rows = np.asarray(lay.rows)
    for t in range(6):
        seg = rows[offs[t]:offs[t + 1]]
        assert ((seg // 8) == t).all()


def test_vocab_tile_windows_cover_all_draws():
    """Every real draw's vocab tile lies inside its batch tile's window, and
    all-padding batch tiles have empty windows (vcount == 0)."""
    tokens, mask = _toy(d=7, l=9, v=32, mask_p=0.4)
    tile_v, tile_b = 4, 16
    lay = segment.build_layout(tokens, mask, 32, tile_v=tile_v, tile_b=tile_b)
    rows = np.asarray(lay.rows).reshape(-1, tile_b)
    vstart, vcount = np.asarray(lay.vstart), np.asarray(lay.vcount)
    for bi in range(rows.shape[0]):
        real = rows[bi] < 32
        if not real.any():
            assert vcount[bi] == 0
            continue
        tiles = rows[bi][real] // tile_v
        assert vstart[bi] <= tiles.min()
        assert tiles.max() < vstart[bi] + vcount[bi]


def test_chunked_layouts_partition_stream():
    tokens, mask = _toy(d=8, l=12, v=32)
    bounds = (0, 4, 8, 12)
    lays = segment.build_chunked_layouts(tokens, mask, 32, bounds=bounds,
                                         tile_v=8, tile_b=32)
    assert len(lays) == 3
    total = sum(int(l_.hist.sum()) for l_ in lays)
    assert total == int(np.asarray(mask).sum())


def test_pick_tile():
    assert segment.pick_tile(300, 64) == 60
    assert segment.pick_tile(256, 64) == 64
    assert segment.pick_tile(7, 64) == 7
    assert segment.pick_tile(13, 4) == 1


def test_pick_tile_vmem():
    # small model: whole vocab in one tile (budget 65536//64=1024 ≥ 300)
    assert segment.pick_tile_vmem(300, 64) == 300
    # production-ish K: tiles shrink to fit, divisor of V
    assert segment.pick_tile_vmem(2048, 2048) == 32
    assert segment.pick_tile_vmem(300, 1024) == 60
    v, k = 1 << 20, 256
    t = segment.pick_tile_vmem(v, k)
    assert v % t == 0 and t * k <= 65536
