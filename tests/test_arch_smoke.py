"""Per-architecture smoke tests (assignment contract).

For each of the 10 assigned architectures: instantiate a REDUCED variant of
the same family (2 layers, d_model ≤ 512, ≤ 4 experts — ``configs.base.
reduced``), run one forward and one train step on CPU, and assert output
shapes + no NaNs.  Decode-capable families also check a prefill→decode
round-trip against the pure forward pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step

ALL_ARCHS = sorted(ARCHITECTURES)
B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    batch_d = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                            cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch_d["patch_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.n_patches, cfg.vision_dim), jnp.bfloat16)
    if cfg.family == "audio":
        batch_d["frames"] = jax.random.normal(
            ks[2], (batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch_d


@pytest.fixture(scope="module")
def arch_state():
    """Cache (cfg, params, batch) per arch across the module's tests."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(ARCHITECTURES[arch])
            key = jax.random.PRNGKey(hash(arch) % 2**31)
            params = model_lib.init_params(cfg, key)
            batch = make_batch(cfg, jax.random.fold_in(key, 1))
            cache[arch] = (cfg, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_finite(arch, arch_state):
    cfg, params, batch = arch_state(arch)
    hidden, aux = model_lib.forward(cfg, params, batch, remat=False)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), f"{arch}: non-finite hidden"
    assert bool(jnp.isfinite(aux).all())
    logits = model_lib.logits_fn(cfg, params, hidden)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, arch_state):
    cfg, params, batch = arch_state(arch)
    tcfg = TrainConfig(microbatches=1, loss_chunk=16, warmup=0, total_steps=10)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = adamw.init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss NaN"
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0, f"{arch}: zero gradient"
    # params must actually move
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0.0
    # every leaf stays finite
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    assert int(opt2.step) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_microbatched_matches(arch, arch_state):
    """Gradient accumulation over 2 microbatches ≈ single-shot step."""
    cfg, params, batch = arch_state(arch)
    opt = adamw.init(params)
    out = {}
    for mb in (1, 2):
        tcfg = TrainConfig(microbatches=mb, loss_chunk=16, warmup=0,
                           total_steps=10)
        step = jax.jit(make_train_step(cfg, tcfg))
        _, _, metrics = step(params, opt, batch)
        out[mb] = float(metrics["loss"])
    # mean of per-microbatch losses == global loss only when microbatches
    # carry equal token counts — true here (full mask except final position).
    assert abs(out[1] - out[2]) < 5e-2 * max(1.0, abs(out[1]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_roundtrip(arch, arch_state):
    """prefill(S tokens) then decode_step must agree with forward on S+1."""
    cfg, params, _ = arch_state(arch)
    if cfg.family == "moe":
        # Capacity-based routing drops tokens batch-dependently, so a
        # 33-token forward and a 32+1 prefill+decode legitimately differ at
        # production capacity_factor.  The cache roundtrip is what this test
        # checks — lift capacity so no token is ever dropped.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(7)
    seq = S
    batch = make_batch(cfg, key, batch=1, seq=seq + 1)
    full_tokens = batch["tokens"]

    # Reference: full forward over S+1 tokens, logits at the last position.
    hidden, _ = model_lib.forward(cfg, params, batch, remat=False)
    ref_logits = model_lib.logits_fn(cfg, params, hidden[:, -1:])

    # prefill on the first S tokens, then one decode step.
    pre_batch = dict(batch)
    pre_batch["tokens"] = full_tokens[:, :seq]
    max_len = seq + 8
    first_logits, cache = model_lib.prefill(cfg, params, pre_batch, max_len)
    assert int(cache["pos"]) == seq
    logits, cache2 = model_lib.decode_step(cfg, params, cache,
                                           full_tokens[:, seq:seq + 1])
    assert logits.shape == (1, 1, cfg.padded_vocab)
    assert int(cache2["pos"]) == seq + 1
    assert bool(jnp.isfinite(logits).all())

    ref = np.asarray(ref_logits, np.float32)[0, 0, :cfg.vocab_size]
    got = np.asarray(logits, np.float32)[0, 0, :cfg.vocab_size]
    # bf16 KV caches + different contraction orders: compare top-1 and
    # correlation instead of exact values.
    assert np.argmax(ref) == np.argmax(got), f"{arch}: decode diverges"
    corr = np.corrcoef(ref, got)[0, 1]
    assert corr > 0.99, f"{arch}: decode/forward corr {corr}"


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "rwkv6-3b", "zamba2-2.7b"])
def test_multi_step_decode(arch, arch_state):
    """8 consecutive decode steps stay finite and advance the cache."""
    cfg, params, _ = arch_state(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(3), batch=2, seq=8)
    logits, cache = model_lib.prefill(cfg, params, batch, 32)
    step = jax.jit(lambda c, t: model_lib.decode_step(cfg, params, c, t))
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    for i in range(8):
        logits, cache = step(cache, tok)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: step {i} NaN"
        tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    assert int(cache["pos"]) == 16


def test_all_archs_registered():
    assert len(ARCHITECTURES) == 10
    fams = {c.family for c in ARCHITECTURES.values()}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_analytic_matches_actual(arch):
    """count_params_analytic (used for MODEL_FLOPS) must match the real
    pytree within 2% on the reduced config."""
    cfg = reduced(ARCHITECTURES[arch])
    params = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k), jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    analytic = model_lib.count_params_analytic(cfg)
    assert abs(actual - analytic) / actual < 0.02, (arch, actual, analytic)
