"""Tests for the token-sorted, tile-skipping MHW pipeline.

Three layers of guarantees:

1. kernel exactness — the tile-skipping kernels must match their pure-jnp
   oracles bit-for-bit given the same uniforms, including streams whose
   vocab tiles are mostly empty (the skip path);
2. sweep consistency — the sorted sweep's sufficient statistics stay
   consistent with its assignments (a permutation-consistent no-op when
   nothing moves);
3. statistical equivalence — sorted and scan layouts reach the same
   perplexity after 5 sweeps within tolerance (the acceptance bar of the
   sorted relaxation: speed must not trade correctness).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family, lda, mhw, pdp, stirling
from repro.data import segment
from repro.kernels import alias_build, alias_sample, mhw_fused, ops, ref
from tests.conftest import make_family_cfg, make_synthetic_corpus


def _sorted_rows(key, b, lo, hi, v, n_pad=0):
    """Sorted row stream concentrated in [lo, hi) with trailing sentinels."""
    rows = jax.random.randint(key, (b - n_pad,), lo, hi, jnp.int32)
    rows = jnp.sort(rows)
    return jnp.concatenate([rows, jnp.full((n_pad,), v, jnp.int32)])


def _windows(rows, v, tile_v, tile_b):
    rs = np.asarray(rows).reshape(-1, tile_b)
    has = rs[:, 0] < v
    last = np.max(np.where(rs < v, rs, -1), axis=1)
    vstart = np.where(has, rs[:, 0] // tile_v, 0).astype(np.int32)
    vcount = np.where(has, last // tile_v - vstart + 1, 0).astype(np.int32)
    return jnp.asarray(vstart), jnp.asarray(vcount)


@pytest.mark.parametrize("v,k,b,tile_v,tile_b,lo,hi,n_pad", [
    (64, 32, 512, 16, 128, 0, 64, 0),      # dense occupancy
    (128, 16, 256, 16, 64, 32, 48, 0),     # one narrow band: most tiles empty
    (64, 8, 256, 8, 64, 0, 9, 37),         # skewed + trailing padding
])
def test_alias_sample_sorted_exact(v, k, b, tile_v, tile_b, lo, hi, n_pad):
    """Tile-skipping draws equal the oracle, draws in skipped tiles and all."""
    key = jax.random.PRNGKey(v + b)
    p = jax.random.gamma(key, 0.3, (v, k)) + 1e-4
    prob, al, _ = alias_build.alias_build(p, tile_r=8)
    rows = _sorted_rows(jax.random.fold_in(key, 1), b, lo, hi, v, n_pad)
    slot = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, k, jnp.int32)
    coin = jax.random.uniform(jax.random.fold_in(key, 3), (b,))
    vstart, vcount = _windows(rows, v, tile_v, tile_b)
    out_k = alias_sample.alias_sample_sorted(prob, al, rows, slot, coin,
                                             vstart, vcount, tile_v=tile_v,
                                             tile_b=tile_b)
    out_r = ref.alias_sample_sorted_ref(prob, al, rows, slot, coin)
    assert bool(jnp.all(out_k == out_r))


@pytest.mark.parametrize("prior_kind", ["lda", "hdp"])
@pytest.mark.parametrize("v,k,b,tile_v,tile_b,lo,hi,n_pad,steps", [
    (60, 16, 384, 12, 128, 0, 60, 0, 2),
    (120, 32, 256, 12, 64, 24, 60, 0, 3),   # most vocab tiles empty
    (60, 16, 256, 12, 64, 0, 7, 61, 2),     # skew + padding
])
def test_mhw_fused_kernel_vs_oracle(v, k, b, tile_v, tile_b, lo, hi, n_pad,
                                    steps, prior_kind):
    """The fused draw+accept kernel is bit-identical to mhw.sorted_chain —
    with the uniform LDA prior α·1 and a non-uniform HDP prior b1·θ0."""
    key = jax.random.PRNGKey(v * k + b)
    alpha, beta = 0.1, 0.01
    beta_bar = beta * v
    n_wk = jax.random.gamma(key, 1.0, (v, k)) * 5
    n_k = n_wk.sum(0)
    lm = (n_wk + beta) / (n_k[None, :] + beta_bar)
    if prior_kind == "lda":
        prior = jnp.full((k,), alpha, jnp.float32)
    else:  # HDP: dense term b1·θ0_t
        theta0 = jax.random.dirichlet(jax.random.fold_in(key, 9),
                                      jnp.ones((k,)))
        prior = 2.0 * theta0
    stale = prior[None, :] * lm
    tabs = ops.build_tables(stale, tile_r=segment.pick_tile(v, 8))

    rows = _sorted_rows(jax.random.fold_in(key, 1), b, lo, hi, v, n_pad)
    z0 = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, k, jnp.int32)
    # raw doc rows: ≥1 at the token's own topic so the in-kernel ^{-di}
    # removal keeps the sparse weights nonnegative, as in a real sweep
    ndk = jax.random.gamma(jax.random.fold_in(key, 3), 0.5, (b, k))
    ndk = ndk.at[jnp.arange(b), z0].add(1.0)
    ks = jax.random.split(jax.random.fold_in(key, 4), 5)
    slot = jax.random.randint(ks[0], (steps, b), 0, k, jnp.int32)
    uni = [jax.random.uniform(ks[i], (steps, b)) for i in range(1, 5)]
    vstart, vcount = _windows(rows, v, tile_v, tile_b)

    out_k = mhw_fused.mhw_sweep_fused(
        tabs.prob, tabs.alias, tabs.mass, stale, n_wk, n_k, prior, rows, z0,
        ndk, slot, *uni, vstart, vcount, tile_v=tile_v, tile_b=tile_b,
        n_steps=steps, beta=beta, beta_bar=beta_bar)
    out_r = ref.mhw_sweep_sorted_ref(
        tabs.prob, tabs.alias, tabs.mass, stale, n_wk, n_k, prior, rows, z0,
        ndk, slot, *uni, beta=beta, beta_bar=beta_bar)
    assert bool(jnp.all(out_k == out_r)), \
        f"{int(jnp.sum(out_k != out_r))} of {b} draws differ"
    # padding sentinels keep their init state
    if n_pad:
        assert bool(jnp.all(out_k[-n_pad:] == z0[-n_pad:]))


@pytest.mark.parametrize("v,k,b,tile_v,tile_b,lo,hi,n_pad,steps", [
    (64, 8, 384, 16, 128, 0, 64, 0, 2),
    (128, 8, 256, 16, 64, 32, 48, 0, 3),    # most vocab tiles empty
    (64, 8, 256, 16, 64, 0, 9, 47, 2),      # skew + padding
])
def test_pdp_fused_kernel_vs_oracle(v, k, b, tile_v, tile_b, lo, hi, n_pad,
                                    steps):
    """The fused PDP kernel (2K joint outcomes, in-VMEM Stirling factors)
    is bit-identical to pdp.sorted_chain_pdp."""
    key = jax.random.PRNGKey(v * k + b + 1)
    cfg = pdp.PDPConfig(n_topics=k, vocab_size=v, mh_steps=steps,
                        stirling_n_max=128, concentration=5.0)
    m_wk = jnp.floor(jax.random.gamma(key, 1.0, (v, k)) * 3)
    s_wk = jnp.minimum(jnp.ceil(m_wk * 0.5), m_wk)
    shared = pdp.SharedStats(m_wk=m_wk, s_wk=s_wk, m_k=m_wk.sum(0),
                             s_k=s_wk.sum(0))
    tabs, stale = pdp.build_alias(cfg, shared)
    stirl = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
    prior = jnp.full((2 * k,), cfg.alpha, jnp.float32)

    rows = _sorted_rows(jax.random.fold_in(key, 1), b, lo, hi, v, n_pad)
    e0 = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, 2 * k,
                            jnp.int32)
    ndk = jnp.floor(jax.random.gamma(jax.random.fold_in(key, 3), 0.5,
                                     (b, k)) * 2)
    ndk = ndk.at[jnp.arange(b), e0 % k].add(1.0)
    ks = jax.random.split(jax.random.fold_in(key, 4), 5)
    slot = jax.random.randint(ks[0], (steps, b), 0, 2 * k, jnp.int32)
    uni = [jax.random.uniform(ks[i], (steps, b)) for i in range(1, 5)]
    vstart, vcount = _windows(rows, v, tile_v, tile_b)

    out_k = mhw_fused.pdp_sweep_fused(
        tabs.prob, tabs.alias, tabs.mass, stale, m_wk, s_wk, shared.m_k,
        shared.s_k, stirl, prior, rows, e0, ndk, slot, *uni, vstart, vcount,
        tile_v=tile_v, tile_b=tile_b, n_steps=steps, b_conc=cfg.concentration,
        a_disc=cfg.discount, gamma=cfg.gamma, gamma_bar=cfg.gamma * v)
    out_r = ref.pdp_sweep_sorted_ref(
        tabs.prob, tabs.alias, tabs.mass, stale, m_wk, s_wk, shared.m_k,
        shared.s_k, stirl, prior, rows, e0, ndk, slot, *uni,
        b=cfg.concentration, a=cfg.discount, gamma=cfg.gamma,
        gamma_bar=cfg.gamma * v)
    assert bool(jnp.all(out_k == out_r)), \
        f"{int(jnp.sum(out_k != out_r))} of {b} draws differ"
    if n_pad:
        assert bool(jnp.all(out_k[-n_pad:] == e0[-n_pad:]))
    # joint outcomes stay in range
    assert bool(jnp.all((out_k >= 0) & (out_k < 2 * k)))


def test_ops_sample_rows_sorted_statistics():
    """The tile-skipping ops wrapper draws from the right distributions
    (end-to-end through key-splitting and the segment windows)."""
    v, k = 32, 16
    key = jax.random.PRNGKey(0)
    p = jax.random.gamma(key, 0.5, (v, k)) + 1e-3
    tables = ops.build_tables(p, tile_r=8)
    # sorted stream: 4000 draws per row, plus a trailing all-padding tile
    rows = jnp.repeat(jnp.arange(v), 4000)
    rows = jnp.concatenate([rows, jnp.full((512,), v, jnp.int32)])
    vstart, vcount = _windows(rows, v, 8, 512)
    s = np.asarray(ops.sample_rows_sorted(tables, rows, vstart, vcount,
                                          jax.random.PRNGKey(1), tile_v=8,
                                          tile_b=512))
    assert (s[-512:] == 0).all(), "padding sentinels draw 0"
    s = s[:-512].reshape(v, -1)
    for r in range(0, v, 7):
        emp = np.bincount(s[r], minlength=k) / s.shape[1]
        refd = np.asarray(p[r] / p[r].sum())
        assert 0.5 * np.abs(emp - refd).sum() < 0.05


def test_mhw_fused_moves_and_respects_empty_tiles():
    """Sanity: the chain actually moves states, and a stream confined to one
    vocab tile leaves every other tile's worth of draws untouched."""
    v, k, b = 64, 16, 256
    key = jax.random.PRNGKey(0)
    n_wk = jax.random.gamma(key, 1.0, (v, k)) * 5
    n_k = n_wk.sum(0)
    stale = 0.1 * (n_wk + 0.01) / (n_k[None, :] + 0.64)
    tabs = ops.build_tables(stale, tile_r=8)
    rows = _sorted_rows(jax.random.fold_in(key, 1), b, 8, 16, v)  # tile 1 only
    z0 = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, k, jnp.int32)
    ndk = jax.random.gamma(jax.random.fold_in(key, 3), 0.5, (b, k))
    ndk = ndk.at[jnp.arange(b), z0].add(1.0)
    vstart, vcount = _windows(rows, v, 8, 64)
    np.testing.assert_array_equal(np.asarray(vcount), np.ones(4))
    np.testing.assert_array_equal(np.asarray(vstart), np.ones(4))
    prior = jnp.full((k,), 0.1, jnp.float32)
    out = ops.mhw_sweep_sorted(tabs, stale, n_wk, n_k, prior, rows, z0, ndk,
                               vstart, vcount, jax.random.fold_in(key, 4),
                               mh_steps=2, beta=0.01,
                               beta_bar=0.64, tile_v=8, tile_b=64)
    assert float(jnp.mean((out != z0).astype(jnp.float32))) > 0.2


@pytest.fixture(scope="module")
def tiny_corpus():
    return make_synthetic_corpus(n_topics=6, vocab=96, n_docs=48, doc_len=32,
                                 seed=3)


def _run_sweeps(cfg, tokens, mask, layout, seed, n_sweeps=5, lays=None):
    local, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    for i in range(n_sweeps):
        tables, stale = lda.build_alias(cfg, shared)
        local, dwk, dk = lda.sweep(
            cfg, local, shared, tables, stale, tokens, mask,
            jax.random.fold_in(jax.random.PRNGKey(seed), i),
            method="mhw", layout=layout, sorted_layouts=lays)
        shared = lda.apply_delta(shared, dwk, dk)
    return local, shared


def test_sorted_sweep_statistics_consistent(tiny_corpus):
    """After a sorted sweep, n_dk / the deltas agree with the assignments —
    the sort → sample → unsort round trip is permutation-consistent."""
    tokens, mask, _ = tiny_corpus
    cfg = lda.LDAConfig(n_topics=24, vocab_size=96, mh_steps=2)
    local, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = lda.build_alias(cfg, shared)
    local2, dwk, dk = lda.sweep(cfg, local, shared, tables, stale, tokens,
                                mask, jax.random.PRNGKey(1), method="mhw",
                                layout="sorted")
    # counts derived from z must equal the incrementally-updated counts
    np.testing.assert_allclose(np.asarray(lda.count_dk(cfg, local2.z, mask)),
                               np.asarray(local2.n_dk), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(lda.count_wk(cfg, tokens, local2.z, mask)),
        np.asarray(shared.n_wk + dwk), atol=1e-4)
    # masked positions never move
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(local2.z)[~m],
                                  np.asarray(local.z)[~m])
    # delta mass is conserved (a sweep moves topics, not tokens)
    assert abs(float(dk.sum())) < 1e-3


def test_sorted_matches_scan_perplexity():
    """Acceptance bar: sorted and scan layouts agree on held-out perplexity
    after 5 sweeps on the synthetic power-law corpus, within 2%.

    Averaged over 3 paired sweep-RNG seeds: a single 5-sweep run on this
    corpus carries ~±1.5% MC noise (seed-to-seed spread of the *scan* path
    alone), which would swamp the ~1% systematic effect of the sorted
    relaxation.  Deterministic given the fixed keys.  The measurement
    protocol is shared with bench_throughput's artifact cross-check
    (``common.lda_sweep_perplexity``) so the two cannot drift.
    """
    from benchmarks import common
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    ccfg = CorpusConfig(n_topics=8, vocab_size=300, n_docs=64, doc_len=48,
                        seed=5)
    tokens, mask, _ = make_topic_corpus(ccfg)
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    cfg = lda.LDAConfig(n_topics=64, vocab_size=300, mh_steps=2)
    means = {
        layout: sum(common.lda_sweep_perplexity(cfg, tokens, mask, layout,
                                                seed)
                    for seed in (2, 3, 4)) / 3
        for layout in ("scan", "sorted")
    }
    rel = abs(means["sorted"] - means["scan"]) / means["scan"]
    assert rel < 0.02, means


def test_sorted_sweep_with_hoisted_layouts_matches_inline(tiny_corpus):
    """Prebuilt chunk layouts (the production path) give bit-identical
    sweeps to the build-inside-sweep convenience path."""
    tokens, mask, _ = tiny_corpus
    cfg = lda.LDAConfig(n_topics=16, vocab_size=96, mh_steps=2)
    lays = lda.build_sorted_layouts(cfg, tokens, mask)
    l_inline, _ = _run_sweeps(cfg, tokens, mask, "sorted", seed=4, n_sweeps=2)
    l_hoist, _ = _run_sweeps(cfg, tokens, mask, "sorted", seed=4, n_sweeps=2,
                             lays=lays)
    np.testing.assert_array_equal(np.asarray(l_inline.z),
                                  np.asarray(l_hoist.z))


def test_sorted_requires_mhw():
    tokens = jnp.zeros((4, 8), jnp.int32)
    mask = jnp.ones((4, 8), bool)
    cfg = lda.LDAConfig(n_topics=4, vocab_size=16)
    local, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = lda.build_alias(cfg, shared)
    with pytest.raises(ValueError, match="sorted"):
        lda.sweep(cfg, local, shared, tables, stale, tokens, mask,
                  jax.random.PRNGKey(1), method="exact", layout="sorted")


# ---------------------------------------------------------------------------
# Sorted layout for every family through the ModelFamily protocol
# ---------------------------------------------------------------------------

def _family_cfg(name):
    return make_family_cfg(name, n_topics=12, vocab_size=96)


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_family_sorted_sweep_statistics_consistent(name, tiny_corpus):
    """After a sorted sweep of any family, the maintained sufficient
    statistics agree bit-exactly with the statistics recomputed from the
    final assignments — the sort → sample → unsort round trip is
    permutation-consistent, as in the scan layout."""
    tokens, mask, _ = tiny_corpus
    fam = family.get(name)
    cfg = _family_cfg(name)
    local, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    tables, stale = fam.build_alias(cfg, shared)
    local2, deltas = fam.sweep(cfg, local, shared, tables, stale, tokens,
                               mask, jax.random.PRNGKey(1), method="mhw",
                               layout="sorted")
    counts = fam.count_stats(cfg, tokens, mask, local2)
    stats = fam.stats_dict(shared)
    for n in fam.conserved_stats:
        np.testing.assert_array_equal(np.asarray(counts[n]),
                                      np.asarray(stats[n] + deltas[n]))
    # n_dk consistent with assignments
    n_dk = jnp.einsum(
        "dl,dlk->dk", mask.astype(jnp.float32),
        jax.nn.one_hot(local2.z, cfg.n_topics, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(n_dk), np.asarray(local2.n_dk),
                               atol=1e-4)
    # masked positions never move; the sweep moved something; mass conserved
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(local2.z)[~m],
                                  np.asarray(local.z)[~m])
    assert float(jnp.mean((local2.z != local.z)[mask].astype(jnp.float32))) \
        > 0.1
    for n in fam.delta_names:
        assert abs(float(deltas[n].sum())) < 1e-3 or n == "s_wk"


@pytest.mark.parametrize("name", ["pdp", "hdp"])
def test_family_sorted_matches_scan_perplexity(name):
    """Acceptance bar extended to PDP/HDP: sorted and scan layouts agree on
    held-out perplexity after 4 single-client sweeps, seed-averaged (same
    protocol as the LDA test above, shared with the benchmark artifact via
    ``common.family_sweep_perplexity``)."""
    from benchmarks import common
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    ccfg = CorpusConfig(n_topics=8, vocab_size=240, n_docs=48, doc_len=32,
                        seed=5)
    tokens, mask, _ = make_topic_corpus(ccfg)
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    cfg = make_family_cfg(name, n_topics=16, vocab_size=240)
    means = {
        layout: sum(common.family_sweep_perplexity(cfg, tokens, mask,
                                                   layout, seed, n_sweeps=4)
                    for seed in (2, 3)) / 2
        for layout in ("scan", "sorted")
    }
    rel = abs(means["sorted"] - means["scan"]) / means["scan"]
    assert rel < 0.05, means


# ---------------------------------------------------------------------------
# K-tiling: the tile_k staging axis (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _mhw_inputs(v=60, k=16, b=256, lo=0, hi=60, steps=2):
    key = jax.random.PRNGKey(v * k + b)
    alpha, beta = 0.1, 0.01
    beta_bar = beta * v
    n_wk = jax.random.gamma(key, 1.0, (v, k)) * 5
    n_k = n_wk.sum(0)
    prior = jnp.full((k,), alpha, jnp.float32)
    stale = prior[None, :] * (n_wk + beta) / (n_k[None, :] + beta_bar)
    tabs = ops.build_tables(stale, tile_r=segment.pick_tile(v, 8))
    rows = _sorted_rows(jax.random.fold_in(key, 1), b, lo, hi, v)
    z0 = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, k,
                            jnp.int32)
    ndk = jax.random.gamma(jax.random.fold_in(key, 3), 0.5, (b, k))
    ndk = ndk.at[jnp.arange(b), z0].add(1.0)
    ks = jax.random.split(jax.random.fold_in(key, 4), 5)
    slot = jax.random.randint(ks[0], (steps, b), 0, k, jnp.int32)
    uni = [jax.random.uniform(ks[i], (steps, b)) for i in range(1, 5)]
    return (tabs, stale, n_wk, n_k, prior, rows, z0, ndk, slot, uni,
            beta, beta_bar, steps)


@pytest.mark.parametrize("tile_k", [4, 8, 16])
def test_mhw_fused_tile_k_bitexact(tile_k):
    """The K-staging grid axis is pure data movement: for any tile_k the
    fused kernel's draws equal the untiled kernel's and the oracle's,
    bit for bit."""
    (tabs, stale, n_wk, n_k, prior, rows, z0, ndk, slot, uni,
     beta, beta_bar, steps) = _mhw_inputs()
    vstart, vcount = _windows(rows, 60, 12, 64)

    def run(tk):
        return mhw_fused.mhw_sweep_fused(
            tabs.prob, tabs.alias, tabs.mass, stale, n_wk, n_k, prior,
            rows, z0, ndk, slot, *uni, vstart, vcount, tile_v=12,
            tile_b=64, n_steps=steps, beta=beta, beta_bar=beta_bar,
            tile_k=tk)

    out_r = ref.mhw_sweep_sorted_ref(
        tabs.prob, tabs.alias, tabs.mass, stale, n_wk, n_k, prior, rows,
        z0, ndk, slot, *uni, beta=beta, beta_bar=beta_bar)
    assert bool(jnp.all(run(tile_k) == out_r))
    assert bool(jnp.all(run(tile_k) == run(None)))


@pytest.mark.parametrize("tile_k", [2, 4, 8])
def test_pdp_fused_tile_k_bitexact(tile_k):
    """Same staging argument for the PDP kernel's 2K joint-outcome axis
    (e-tiles stage always, K-side stats only for the first nk tiles)."""
    v, k, b, steps = 64, 8, 256, 2
    key = jax.random.PRNGKey(v * k + b + 1)
    cfg = pdp.PDPConfig(n_topics=k, vocab_size=v, mh_steps=steps,
                        stirling_n_max=128, concentration=5.0)
    m_wk = jnp.floor(jax.random.gamma(key, 1.0, (v, k)) * 3)
    s_wk = jnp.minimum(jnp.ceil(m_wk * 0.5), m_wk)
    shared = pdp.SharedStats(m_wk=m_wk, s_wk=s_wk, m_k=m_wk.sum(0),
                             s_k=s_wk.sum(0))
    tabs, stale = pdp.build_alias(cfg, shared)
    stirl = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
    prior = jnp.full((2 * k,), cfg.alpha, jnp.float32)
    rows = _sorted_rows(jax.random.fold_in(key, 1), b, 0, v, v)
    e0 = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, 2 * k,
                            jnp.int32)
    ndk = jnp.floor(jax.random.gamma(jax.random.fold_in(key, 3), 0.5,
                                     (b, k)) * 2)
    ndk = ndk.at[jnp.arange(b), e0 % k].add(1.0)
    ks = jax.random.split(jax.random.fold_in(key, 4), 5)
    slot = jax.random.randint(ks[0], (steps, b), 0, 2 * k, jnp.int32)
    uni = [jax.random.uniform(ks[i], (steps, b)) for i in range(1, 5)]
    vstart, vcount = _windows(rows, v, 16, 64)

    def run(tk):
        return mhw_fused.pdp_sweep_fused(
            tabs.prob, tabs.alias, tabs.mass, stale, m_wk, s_wk,
            shared.m_k, shared.s_k, stirl, prior, rows, e0, ndk, slot,
            *uni, vstart, vcount, tile_v=16, tile_b=64, n_steps=steps,
            b_conc=cfg.concentration, a_disc=cfg.discount,
            gamma=cfg.gamma, gamma_bar=cfg.gamma * v, tile_k=tk)

    out_r = ref.pdp_sweep_sorted_ref(
        tabs.prob, tabs.alias, tabs.mass, stale, m_wk, s_wk, shared.m_k,
        shared.s_k, stirl, prior, rows, e0, ndk, slot, *uni,
        b=cfg.concentration, a=cfg.discount, gamma=cfg.gamma,
        gamma_bar=cfg.gamma * v)
    assert bool(jnp.all(run(tile_k) == out_r))
    assert bool(jnp.all(run(tile_k) == run(None)))


@pytest.mark.parametrize("name", ["lda", "pdp"])
def test_family_sweep_sorted_tile_k_bitexact(name, tiny_corpus):
    """cfg.tile_k is representation only: the full sorted sweep produces
    byte-identical deltas with and without K-tiling."""
    import dataclasses
    tokens, mask, _ = tiny_corpus
    fam = family.get(name)
    deltas = {}
    for tk in (None, 4):
        cfg = dataclasses.replace(_family_cfg(name), tile_v=12, tile_k=tk)
        local, shared = fam.init_state(cfg, tokens, mask,
                                       jax.random.PRNGKey(0))
        tables, stale = fam.build_alias(cfg, shared)
        _, deltas[tk] = fam.sweep_sorted(cfg, local, shared, tables,
                                         stale, tokens, mask,
                                         jax.random.PRNGKey(1), None)
    for n in deltas[None]:
        np.testing.assert_array_equal(np.asarray(deltas[None][n]),
                                      np.asarray(deltas[4][n]), err_msg=n)
