"""The explicit ParameterServer API (core/server.py, DESIGN.md §9):
vocabulary sharding, pluggable consistency, clocks, and the server-side
changed-row accounting.

Contracts:

1. sharding is representation-only — any ``n_server_shards`` is bit-exact
   with the unsharded dense pytree (assembly is pure concatenation and
   all arithmetic runs on the assembled view);
2. BSP through the server is bit-exact with the reference loop (the
   migration oracle — also covered family-wide in test_round_compile);
3. SSP and async keep the count-conservation contract exactly (staleness
   delays what clients *see*, never what the server *applies*) and match
   their Python reference loop bit-for-bit;
4. SSP's versioned cache refreshes on the staleness-bound schedule, and
   the alias proposal rebuilds exactly on refresh rounds (the measured
   throughput win);
5. one compiled-round trace per (family, layout, policy) — the refresh
   flag, projection cadence and failure mask all enter traced.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family as family_mod
from repro.core import server as server_mod
from repro.core.fault import FaultPlan
from repro.core.server import (Async, BSP, ShardSpec, SSP, make_consistency)
from repro.engine import Trainer, TrainerConfig
from repro.engine import round as round_mod
from tests.conftest import make_family_cfg, make_synthetic_corpus

VOCAB = 64


def _cfg(name, k=4):
    return make_family_cfg(name, n_topics=k, vocab_size=VOCAB)


@pytest.fixture(scope="module")
def corpus():
    return make_synthetic_corpus(n_topics=4, vocab=VOCAB, n_docs=16,
                                 doc_len=12, seed=3)


# ---------------------------------------------------------------------------
# ShardSpec / policy parsing
# ---------------------------------------------------------------------------

def test_shard_spec_row_ranges():
    spec = ShardSpec(n_rows=10, n_shards=3)
    assert spec.bounds == (0, 3, 6, 10)
    assert [spec.rows_of(s) for s in range(3)] == [(0, 3), (3, 6), (6, 10)]
    r2s = spec.row_to_shard()
    assert r2s.shape == (10,)
    # the map agrees with the ranges, covers every row, and shard_of
    # matches it pointwise
    for row in range(10):
        lo, hi = spec.rows_of(r2s[row])
        assert lo <= row < hi
        assert spec.shard_of(row) == r2s[row]
    x = jnp.arange(10 * 2, dtype=jnp.float32).reshape(10, 2)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s) for s in spec.split(x)]), np.asarray(x))


def test_shard_spec_validates():
    with pytest.raises(ValueError):
        ShardSpec(n_rows=4, n_shards=5)
    with pytest.raises(ValueError):
        ShardSpec(n_rows=4, n_shards=0)


def test_make_consistency_parsing():
    assert isinstance(make_consistency("bsp"), BSP)
    assert isinstance(make_consistency("async"), Async)
    assert make_consistency("ssp:3").bound == 3
    assert make_consistency("ssp(2)").bound == 2
    assert make_consistency("ssp").bound == 1
    assert make_consistency("ssp:2").key == "ssp(2)"
    pol = SSP(bound=4)
    assert make_consistency(pol) is pol
    with pytest.raises(ValueError, match="consistency"):
        make_consistency("eventually-maybe")
    with pytest.raises(ValueError, match="bound"):
        SSP(bound=-1)
    # a negative bound must reach the validator, not silently parse as
    # its absolute value
    with pytest.raises(ValueError, match="bound"):
        make_consistency("ssp:-1")


def test_ssp_init_state_leaves_not_aliased(corpus):
    """The SSP cache must be a materialized copy, never an alias of the
    canonical shards/aux: the whole ServerState is donated to the
    compiled round, and donating one buffer twice is a runtime error on
    donating backends (CPU skips donation, so CI would mask an alias)."""
    tokens, mask, _ = corpus
    fam = family_mod.get("lda")
    cfg = _cfg("lda")
    _, shared = fam.init_state(cfg, jnp.asarray(tokens), jnp.asarray(mask),
                               jax.random.PRNGKey(0))
    srv = server_mod.make_server(fam, VOCAB, consistency="ssp:2")
    state = srv.init_state(shared, n_clients=2)

    def buf(x):
        try:
            return x.unsafe_buffer_pointer()   # the actual device buffer
        except Exception:
            return id(x)

    leaf_bufs = [buf(x) for x in jax.tree.leaves(state)]
    assert len(leaf_bufs) == len(set(leaf_bufs)), \
        "ServerState leaves alias each other — double donation"


def test_trainer_rejects_bad_consistency(corpus):
    tokens, mask, _ = corpus
    with pytest.raises(ValueError, match="consistency"):
        Trainer(_cfg("lda"), tokens, mask,
                config=TrainerConfig(consistency="gossip"))
    with pytest.raises(ValueError, match="n_shards"):
        Trainer(_cfg("lda"), tokens, mask,
                config=TrainerConfig(n_server_shards=10**6))


# ---------------------------------------------------------------------------
# Sharded store: pull/push/snapshot round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
@pytest.mark.parametrize("n_shards", [1, 3])
def test_server_split_assemble_roundtrip(name, n_shards, corpus):
    tokens, mask, _ = corpus
    fam = family_mod.get(name)
    cfg = _cfg(name)
    _, shared = fam.init_state(cfg, jnp.asarray(tokens), jnp.asarray(mask),
                               jax.random.PRNGKey(0))
    srv = server_mod.make_server(fam, VOCAB, n_shards=n_shards)
    state = srv.init_state(shared, n_clients=2)
    out = fam.stats_dict(srv.snapshot(state))
    for n, v in fam.stats_dict(shared).items():
        np.testing.assert_array_equal(np.asarray(out[n]), np.asarray(v),
                                      err_msg=n)
    # pull(keys): shard-local slices address the canonical rows
    for s in range(n_shards):
        lo, hi = srv.spec.rows_of(s)
        stat = fam.conserved_stats[0]
        (sl,) = srv.pull(state, [(stat, s)])
        np.testing.assert_array_equal(
            np.asarray(sl), np.asarray(fam.stats_dict(shared)[stat][lo:hi]))


@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_sharded_server_bit_exact_with_unsharded(name, corpus):
    """n_server_shards is representation only: identical counts (and all
    shared stats, exactly — no arithmetic touches shard boundaries)."""
    tokens, mask, _ = corpus
    stats = {}
    for n_shards in (1, 4):
        t = Trainer(_cfg(name), tokens, mask, config=TrainerConfig(
            n_clients=2, tau=2, n_server_shards=n_shards))
        for _ in range(3):
            t.step()
        t._sync()
        stats[n_shards] = t.family.stats_dict(t.shared)
    for n in stats[1]:
        np.testing.assert_array_equal(np.asarray(stats[1][n]),
                                      np.asarray(stats[4][n]), err_msg=n)


def test_push_tracks_per_shard_mass_and_clocks(corpus):
    tokens, mask, _ = corpus
    fam = family_mod.get("lda")
    cfg = _cfg("lda")
    _, shared = fam.init_state(cfg, jnp.asarray(tokens), jnp.asarray(mask),
                               jax.random.PRNGKey(0))
    srv = server_mod.make_server(fam, VOCAB, n_shards=4)
    state = srv.init_state(shared, n_clients=3)
    delta = {"n_wk": jnp.zeros((VOCAB, cfg.n_topics))
             .at[5].set(1.0).at[40].set(-2.0)}
    alive = jnp.array([True, False, True])
    state = srv.push(state, delta, alive, track_mass=True)
    # counts applied once, clocks advanced only for pushing clients
    np.testing.assert_array_equal(
        np.asarray(srv.snapshot(state).n_wk),
        np.asarray(shared.n_wk + delta["n_wk"]))
    np.testing.assert_array_equal(np.asarray(state.clocks), [1, 0, 1])
    # per-shard accounting: row 5's mass on its owner shard, row 40's on its
    mass = np.concatenate([np.asarray(m) for m in srv.shard_row_mass(state)])
    expect = np.zeros(VOCAB)
    expect[5] = cfg.n_topics * 1.0
    expect[40] = cfg.n_topics * 2.0
    np.testing.assert_allclose(mass, expect)
    owner5 = srv.spec.shard_of(5)
    lo, _ = srv.spec.rows_of(owner5)
    assert float(srv.shard_row_mass(state)[owner5][5 - lo]) > 0
    # consumption selects exactly the drifted rows and resets the ledger
    rows, valid, state = srv.consume_changed_rows(state, k_rows=8,
                                                  threshold=0.0)
    picked = set(np.asarray(rows)[np.asarray(valid)].tolist())
    assert picked == {5, 40}
    assert all(float(m.sum()) == 0.0 for m in srv.shard_row_mass(state))


# ---------------------------------------------------------------------------
# Consistency policies end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("consistency", ["ssp:2", "async"])
@pytest.mark.parametrize("name", ["lda", "pdp", "hdp"])
def test_policies_conserve_counts_and_match_reference(name, consistency,
                                                      corpus):
    """SSP/async compiled rounds match their Python reference loop
    bit-exactly on count statistics and keep exact count conservation —
    relaxed consistency delays what clients see, never what the server
    applies."""
    tokens, mask, _ = corpus
    trainers = {
        compiled: Trainer(_cfg(name), tokens, mask, config=TrainerConfig(
            n_clients=2, consistency=consistency, compiled=compiled))
        for compiled in (True, False)}
    for _ in range(4):
        for t in trainers.values():
            t.step()
    trainers[True]._sync()
    fam = trainers[True].family
    stats = {c: fam.stats_dict(t.shared) for c, t in trainers.items()}
    for n in fam.conserved_stats:
        np.testing.assert_array_equal(np.asarray(stats[True][n]),
                                      np.asarray(stats[False][n]),
                                      err_msg=n)
    for t in trainers.values():
        assert t.consistency_error() == 0.0
        assert np.all(t.clocks == 4)


def test_ssp_refresh_schedule_and_alias_coupling(corpus):
    """SSP(bound=2): the versioned cache (and with it the alias proposal)
    refreshes at rounds 0, 3, 6, ... — clients run up to 2 rounds ahead
    of the snapshot, and the skipped rebuilds are the throughput win."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
        n_clients=2, consistency="ssp:2"))
    builds = []
    for _ in range(7):
        t.step()
        builds.append(t.alias_builds)
    t._sync()
    # refresh at r=0, r=3, r=6 → 3 builds in 7 rounds (BSP would do 7)
    assert builds == [1, 1, 1, 2, 2, 2, 3]
    assert int(t.pstate.cache_version) == 6
    # the staleness bound held on every pull: r - version <= 2
    for r, b in enumerate(builds):
        version = {1: 0, 2: 3, 3: 6}[b]
        assert r - version <= 2
    # the pulled cache is genuinely stale between refreshes: after the
    # last round (r=6 refreshed at pull time, then pushed), the cache
    # holds the pre-push state, not the canonical one.
    cache_nwk = np.asarray(t.pstate.cache.n_wk)
    canon_nwk = np.asarray(t.shared.n_wk)
    assert not np.array_equal(cache_nwk, canon_nwk)
    assert t.consistency_error() == 0.0


def test_ssp_matches_bsp_when_bound_zero(corpus):
    """SSP(0) refreshes every round — identical counts to BSP (the
    degenerate bound recovers bulk-synchronous behavior)."""
    tokens, mask, _ = corpus
    out = {}
    for consistency in ("bsp", "ssp:0"):
        t = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
            n_clients=2, tau=2, consistency=consistency))
        for _ in range(3):
            t.step()
        t._sync()
        out[consistency] = np.asarray(t.shared.n_wk)
    np.testing.assert_array_equal(out["bsp"], out["ssp:0"])


def test_async_clients_see_in_round_pushes(corpus):
    """Async applies pushes immediately: with two clients the second
    samples against the first's push, so async counts must differ from
    BSP's barrier semantics after one round (while still conserving)."""
    tokens, mask, _ = corpus
    out = {}
    for consistency in ("bsp", "async"):
        t = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
            n_clients=2, consistency=consistency))
        t.step()
        t._sync()
        assert t.consistency_error() == 0.0
        out[consistency] = np.asarray(t.shared.n_wk)
    assert not np.array_equal(out["bsp"], out["async"])


@pytest.mark.parametrize("consistency", ["ssp:2", "async"])
def test_policy_rounds_trace_once(consistency, corpus):
    """One trace per (family, layout, policy): rounds spanning refresh
    and non-refresh pulls, projection cadence and a failure window must
    not retrace the compiled round."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg("hdp"), tokens, mask, config=TrainerConfig(
        layout="sorted", n_clients=2, consistency=consistency,
        project_every=2, fault_plan=FaultPlan.crash(1, 2, 3)))
    t.step()
    traced_once = t.round_traces
    assert traced_once >= 1
    for _ in range(5):
        t.step()
    t._sync()
    assert t.round_traces == traced_once
    assert t.consistency_error() == 0.0


def test_policy_failure_injection_freezes_clock(corpus):
    """A dead client's push is zeroed and its clock frozen — the signal
    SSP's bound watches on a real deployment."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg("lda"), tokens, mask, config=TrainerConfig(
        n_clients=3, consistency="ssp:1", fault_plan=FaultPlan.crash(1, 0, 2)))
    for _ in range(4):
        t.step()
    t._sync()
    np.testing.assert_array_equal(t.clocks, [4, 2, 4])
    assert t.consistency_error() == 0.0


def test_ssp_converges_near_bsp(corpus):
    """Perplexity sanity on the tiny unit corpus: SSP(2) converges (well
    below the random-init plateau) and lands in BSP's neighborhood.  The
    16-doc corpus is deliberately the worst staleness regime — per-round
    relative drift is huge — so the bound here is loose; the ≤5% gate at
    the bench's corpus scale lives in benchmarks/bench_consistency.py."""
    tokens, mask, _ = corpus
    ppl = {}
    for consistency in ("bsp", "ssp:2"):
        vals = []
        for seed in (0, 1, 2):
            t = Trainer(_cfg("lda"), tokens, mask,
                        config=TrainerConfig(n_clients=2,
                                             consistency=consistency),
                        key=jax.random.PRNGKey(seed))
            for _ in range(12):
                t.step()
            t._sync()
            vals.append(t.perplexity())
        ppl[consistency] = sum(vals) / len(vals)
    rel = abs(ppl["ssp:2"] - ppl["bsp"]) / ppl["bsp"]
    assert rel < 0.2, ppl
