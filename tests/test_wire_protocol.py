"""Wire-protocol unit + fuzz tests (repro.net.protocol / DESIGN.md §11).

The fuzz section drives a *live* ShardServer with malformed frames —
truncated headers, bad magic, unsupported versions, oversized and
negative lengths, mid-payload disconnects — and asserts the server (a)
never hangs, (b) answers a clean ProtocolError/ERROR and closes only the
offending connection, and (c) keeps its shard state byte-identical
through the abuse.
"""

from __future__ import annotations

import socket
import struct
import threading

import numpy as np
import pytest

from repro.net import protocol
from repro.net.client import RemoteParameterServer
from repro.net.protocol import (ConnectionClosed, HEADER, MAGIC, MAX_PAYLOAD,
                                MsgType, ProtocolError, PROTOCOL_VERSION)
from repro.net.server import ShardServer

# Everything here must finish fast; a blocked recv is itself a failure.
SOCK_TIMEOUT = 5.0


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

def test_payload_roundtrip_preserves_dtypes_and_values():
    meta = {"round": 3, "client": 1, "names": ["n_wk"], "f": 0.25}
    arrays = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, -2, 3], dtype=np.int64),
        "c": np.float64(1.5) * np.ones((2, 2)),
    }
    meta2, arrays2 = protocol.unpack_payload(
        protocol.pack_payload(meta, arrays))
    assert meta2 == meta
    assert set(arrays2) == set(arrays)
    for n in arrays:
        assert arrays2[n].dtype == arrays[n].dtype
        np.testing.assert_array_equal(arrays2[n], arrays[n])


def test_payload_roundtrip_no_arrays():
    meta2, arrays2 = protocol.unpack_payload(
        protocol.pack_payload({"ok": True}))
    assert meta2 == {"ok": True}
    assert arrays2 == {}


@pytest.mark.parametrize("payload", [
    b"",                                   # shorter than the meta length
    b"\x00\x00",                           # still shorter
    struct.pack("!I", 999) + b"{}",        # meta_len exceeds payload
    struct.pack("!I", 2) + b"\xff\xfe",    # undecodable UTF-8
    struct.pack("!I", 2) + b"[]",          # JSON but not an object
    struct.pack("!I", 2) + b"{}" + b"not an npz archive",
])
def test_unpack_payload_rejects_garbage(payload):
    with pytest.raises(ProtocolError):
        protocol.unpack_payload(payload)


def test_frame_header_validation():
    good = protocol.pack_frame(MsgType.PULL, {"round": 0})
    mt, length = protocol._validate_header(good[:protocol.HEADER_SIZE])
    assert mt is MsgType.PULL
    assert length == len(good) - protocol.HEADER_SIZE

    def header(magic=MAGIC, version=PROTOCOL_VERSION, msg_type=int(MsgType.PULL),
               flags=0, length=0):
        return HEADER.pack(magic, version, msg_type, flags, length)

    for bad, what in [
        (header(magic=b"EVIL"), "magic"),
        (header(version=PROTOCOL_VERSION + 1), "version"),
        (header(msg_type=200), "unknown type"),
        (header(flags=1), "reserved flags"),
        (header(length=-1), "negative length"),
        (header(length=MAX_PAYLOAD + 1), "oversized length"),
    ]:
        with pytest.raises(ProtocolError):
            protocol._validate_header(bad), what


def test_recv_all_boundary_vs_midread():
    a, b = socket.socketpair()
    a.settimeout(SOCK_TIMEOUT)
    b.settimeout(SOCK_TIMEOUT)
    try:
        b.sendall(b"xyz")
        assert protocol.recv_all(a, 3) == b"xyz"
        # EOF at a frame boundary → clean close.
        b.close()
        with pytest.raises(ConnectionClosed):
            protocol.recv_all(a, 4, at_boundary=True)
    finally:
        a.close()

    a, b = socket.socketpair()
    a.settimeout(SOCK_TIMEOUT)
    try:
        b.sendall(b"xy")
        b.close()
        # EOF two bytes into a four-byte read → truncation, even at a
        # nominal boundary.
        with pytest.raises(ProtocolError) as ei:
            protocol.recv_all(a, 4, at_boundary=True)
        assert not isinstance(ei.value, ConnectionClosed)
    finally:
        a.close()


# ---------------------------------------------------------------------------
# fuzz against a live server
# ---------------------------------------------------------------------------

@pytest.fixture()
def live_server():
    srv = ShardServer("lda", vocab_size=16, n_clients=1, consistency="bsp",
                      barrier_timeout=SOCK_TIMEOUT)
    srv.start()
    yield srv
    srv.close()


def _raw(srv) -> socket.socket:
    sock = socket.create_connection(srv.address, timeout=SOCK_TIMEOUT)
    sock.settimeout(SOCK_TIMEOUT)
    return sock


def _seed_state(srv) -> dict[str, np.ndarray]:
    """INIT the single client so the server holds a sealed store, and
    return an independent copy of it."""
    rps = RemoteParameterServer(["%s:%d" % srv.address], family="lda",
                                n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    from repro.core import family as fam_mod
    fam = fam_mod.get("lda")
    n_wk = np.arange(16 * 4, dtype=np.float32).reshape(16, 4)
    rps.init_push(0, fam.shared_from_dict(
        {"n_wk": n_wk, "n_k": n_wk.sum(0)}))
    state = rps.pull_keys(["n_wk"])
    rps.close()
    return state


def _expect_error_then_close(sock: socket.socket):
    """The server must answer ERROR (best effort) and close; it must
    never leave us blocked."""
    got = b""
    try:
        while len(got) < protocol.HEADER_SIZE:
            chunk = sock.recv(1 << 16)
            if not chunk:
                return None  # closed without the courtesy ERROR — fine
            got += chunk
    except (socket.timeout, ConnectionResetError):
        pytest.fail("server hung or reset instead of ERROR+close")
    mt, length = protocol._validate_header(got[:protocol.HEADER_SIZE])
    assert mt is MsgType.ERROR
    return mt


@pytest.mark.parametrize("frame", [
    b"LVP",                                              # truncated header
    protocol.pack_frame(MsgType.PULL, {})[:protocol.HEADER_SIZE - 4],
    b"EVIL" + protocol.pack_frame(MsgType.PULL, {})[4:],  # bad magic
    HEADER.pack(MAGIC, 99, int(MsgType.PULL), 0, 0),      # bad version
    HEADER.pack(MAGIC, PROTOCOL_VERSION, 200, 0, 0),      # unknown type
    HEADER.pack(MAGIC, PROTOCOL_VERSION, int(MsgType.PULL), 0, -5),
    HEADER.pack(MAGIC, PROTOCOL_VERSION, int(MsgType.PULL), 0,
                MAX_PAYLOAD + 1),
], ids=["trunc3", "trunc12", "magic", "version", "msgtype", "neglen",
        "oversize"])
def test_fuzz_malformed_frames_never_hang(live_server, frame):
    before = _seed_state(live_server)
    sock = _raw(live_server)
    try:
        sock.sendall(frame)
        if len(frame) < protocol.HEADER_SIZE:
            sock.shutdown(socket.SHUT_WR)  # truncation = peer gone
        _expect_error_then_close(sock)
    finally:
        sock.close()
    # The abuse killed one connection, not the store.
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    after = rps.pull_keys(["n_wk"])
    rps.close()
    np.testing.assert_array_equal(before["n_wk"], after["n_wk"])
    assert live_server.stats()["protocol_errors"] >= 1


def test_fuzz_mid_payload_disconnect(live_server):
    before = _seed_state(live_server)
    sock = _raw(live_server)
    try:
        full = protocol.pack_frame(
            MsgType.PUSH, {"round": 0, "client": 0},
            {"n_wk": np.ones((16, 4), np.float32)})
        sock.sendall(full[:protocol.HEADER_SIZE + 10])  # then vanish
    finally:
        sock.close()
    # The half-received PUSH must not have been applied, and the server
    # must still serve new connections promptly.
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    after = rps.pull_keys(["n_wk"])
    rps.close()
    np.testing.assert_array_equal(before["n_wk"], after["n_wk"])


def test_fuzz_garbage_flood_concurrent(live_server):
    """Several connections spraying garbage at once while a good client
    keeps working: the good client must stay correct."""
    before = _seed_state(live_server)
    blobs = [b"\x00" * 64, b"LVPS" + b"\xff" * 60,
             protocol.pack_frame(MsgType.PULL, {})[:7]]

    def abuse(blob: bytes):
        s = _raw(live_server)
        try:
            s.sendall(blob)
            s.shutdown(socket.SHUT_WR)
            try:
                while s.recv(1 << 16):
                    pass
            except OSError:
                pass
        finally:
            s.close()

    threads = [threading.Thread(target=abuse, args=(b,))
               for b in blobs * 3]
    for t in threads:
        t.start()
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    mid = rps.pull_keys(["n_wk"])
    for t in threads:
        t.join(timeout=SOCK_TIMEOUT)
        assert not t.is_alive()
    np.testing.assert_array_equal(before["n_wk"], mid["n_wk"])
    assert rps.server_stats()[0]["protocol_errors"] >= 1
    rps.close()


def test_semantic_error_reply_and_survival(live_server):
    """A well-framed but semantically-invalid request gets an ERROR reply
    (surfaced as RemoteError/ProtocolError client-side) and does not take
    the server down."""
    _seed_state(live_server)
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    with pytest.raises(ProtocolError):
        rps.push(0, 99, {"n_wk": np.zeros((16, 4), np.float32)})  # bad id
    rps.close()
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    assert rps.pull_keys(["n_wk"])["n_wk"].shape == (16, 4)
    rps.close()


def test_hello_mismatch_rejected(live_server):
    from repro.net.client import RemoteError
    with pytest.raises(RemoteError):
        RemoteParameterServer(["%s:%d" % live_server.address],
                              family="lda", n_clients=2,  # server has 1
                              vocab_size=16, timeout=SOCK_TIMEOUT)


# ---------------------------------------------------------------------------
# PUSH_SPARSE frame fuzz (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _sparse_frame(rows, values=None, *, n_rows=16, names=("n_wk",),
                  drop_rows=False):
    """A well-framed PUSH_SPARSE with attacker-controlled indices."""
    rows = np.asarray(rows)
    if values is None:
        values = np.ones((rows.shape[0] if rows.ndim else 0, 4), np.float32)
    meta = {"round": 0, "client": 0, "n_rows": n_rows,
            "sparse": list(names)}
    arrays = {} if drop_rows else {"rows": rows}
    arrays.update({n: values for n in names})
    return protocol.pack_frame(MsgType.PUSH_SPARSE, meta, arrays)


@pytest.mark.parametrize("frame_fn", [
    lambda: _sparse_frame(np.array([99], np.int64)),
    lambda: _sparse_frame(np.array([-1], np.int64)),
    lambda: _sparse_frame(np.array([3, 3], np.int64)),
    lambda: _sparse_frame(np.array([5, 1], np.int64)),
    # uint32 pairs: np.diff would wrap positive without the int64 cast.
    lambda: _sparse_frame(np.array([5, 1], np.uint32)),
    lambda: _sparse_frame(np.array([0, 2**32 - 1], np.uint32)),
    lambda: _sparse_frame(np.array([1], np.int64), drop_rows=True),
    lambda: _sparse_frame(np.array([[1, 2]], np.int64),
                          values=np.ones((1, 4), np.float32)),
    lambda: _sparse_frame(np.array([1.0, 2.0], np.float32)),
    lambda: _sparse_frame(np.array([1], np.int64), n_rows=7),
    lambda: _sparse_frame(np.array([1, 2], np.int64),
                          values=np.ones((3, 4), np.float32)),
    lambda: _sparse_frame(np.array([1, 2], np.int64),
                          values=np.ones((2, 3), np.float32)),
], ids=["oor", "negative", "dup", "unsorted", "unsorted-u32", "oor-u32",
        "no-rows", "rows-2d", "rows-float", "n_rows-mismatch",
        "r-mismatch", "k-mismatch"])
def test_fuzz_sparse_frames_rejected_store_intact(live_server, frame_fn):
    """Malformed-but-well-framed sparse pushes: clean ERROR, no hang, and
    the store stays byte-identical (validation precedes any mutation)."""
    before = _seed_state(live_server)
    sock = _raw(live_server)
    try:
        sock.sendall(frame_fn())
        _expect_error_then_close(sock)
    finally:
        sock.close()
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    after = rps.pull_keys(["n_wk"])
    rps.close()
    np.testing.assert_array_equal(before["n_wk"], after["n_wk"])


def test_fuzz_sparse_mid_payload_disconnect(live_server):
    before = _seed_state(live_server)
    sock = _raw(live_server)
    try:
        full = _sparse_frame(np.array([1, 4], np.int64),
                             values=np.ones((2, 4), np.float32))
        sock.sendall(full[:protocol.HEADER_SIZE + 14])  # then vanish
    finally:
        sock.close()
    rps = RemoteParameterServer(["%s:%d" % live_server.address],
                                family="lda", n_clients=1, vocab_size=16,
                                timeout=SOCK_TIMEOUT)
    after = rps.pull_keys(["n_wk"])
    rps.close()
    np.testing.assert_array_equal(before["n_wk"], after["n_wk"])


def test_sparse_push_applies_bitexact_with_dense():
    """The good path: the same delta pushed dense and sparse (via the
    client's sparse_push encoder) lands on byte-identical stores."""
    delta = np.zeros((16, 4), np.float32)
    delta[2] = [1.0, -2.0, 0.5, 0.0]
    delta[11] = [-1.0, 0.0, 0.0, 3.0]

    results = {}
    for mode in ("dense", "sparse"):
        srv = ShardServer("lda", vocab_size=16, n_clients=1,
                          consistency="bsp", barrier_timeout=SOCK_TIMEOUT)
        srv.start()
        try:
            _seed_state(srv)
            rps = RemoteParameterServer(
                ["%s:%d" % srv.address], family="lda", n_clients=1,
                vocab_size=16, timeout=SOCK_TIMEOUT,
                sparse_push=(mode == "sparse"))
            rps.push(0, 0, {"n_wk": delta})
            results[mode] = rps.pull_keys(["n_wk"])["n_wk"]
            rps.close()
        finally:
            srv.close()
    np.testing.assert_array_equal(results["dense"], results["sparse"])
    # The push genuinely applied (it is not two untouched stores).
    assert results["dense"][2, 1] != 0.0 or results["dense"][2, 0] != 0.0


# ---------------------------------------------------------------------------
# INFER frame fuzz (DESIGN.md §14) — the inference service speaks the same
# wire format; malformed requests must get a clean ERROR without taking
# the batcher down, and the engine output after abuse must stay
# bit-identical to an untouched in-process engine.
# ---------------------------------------------------------------------------

V_INF, K_INF, LEN_INF = 16, 4, 8


@pytest.fixture(scope="module")
def infer_server():
    import jax
    from repro.core import family as fam_mod
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    from repro.serve import ServeConfig, freeze
    from repro.serve.server import InferenceServer

    fam = fam_mod.get("lda")
    cfg = fam.config_cls(n_topics=K_INF, vocab_size=V_INF)
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=K_INF, vocab_size=V_INF, n_docs=8, doc_len=LEN_INF,
        seed=0))
    _, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    snap = freeze(cfg, shared)
    scfg = ServeConfig(max_slots=2, max_len=LEN_INF, n_sweeps=2)
    srv = InferenceServer(snap, scfg, idle_timeout=SOCK_TIMEOUT).start()
    yield srv, snap, scfg
    srv.close()


def _good_doc():
    return (np.arange(6, dtype=np.int32) % V_INF)


def _infer_roundtrip(srv, uid=7, seed=3):
    """A valid INFER through a real client; returns the result."""
    from repro.serve.client import InferenceClient
    with InferenceClient("%s:%d" % srv.address,
                         timeout=SOCK_TIMEOUT * 4) as cli:
        return cli.infer(uid, _good_doc(), seed=seed)


def _reference_result(snap, scfg, uid=7, seed=3):
    from repro.serve import FoldInEngine, InferRequest
    eng = FoldInEngine(snap, scfg)
    return eng.run([InferRequest(uid=uid, tokens=_good_doc(),
                                 seed=seed)])[uid]


def _infer_frame(meta=None, arrays=None):
    if meta is None:
        meta = {"uid": 1, "seed": 0}
    if arrays is None:
        arrays = {"tokens": _good_doc()}
    return protocol.pack_frame(MsgType.INFER, meta, arrays)


@pytest.mark.parametrize("frame_fn", [
    lambda: _infer_frame(meta={"seed": 0}),                  # no uid
    lambda: _infer_frame(meta={"uid": "seven", "seed": 0}),
    lambda: _infer_frame(meta={"uid": True, "seed": 0}),
    lambda: _infer_frame(meta={"uid": 1, "seed": "x"}),
    lambda: _infer_frame(arrays={}),                         # no tokens
    lambda: _infer_frame(arrays={"tokens": np.zeros((2, 3), np.int32)}),
    lambda: _infer_frame(arrays={"tokens": np.ones(4, np.float32)}),
    lambda: _infer_frame(arrays={"tokens": np.zeros(0, np.int32)}),
    lambda: _infer_frame(                                    # oversized doc
        arrays={"tokens": np.zeros(LEN_INF + 1, np.int32)}),
    lambda: _infer_frame(                                    # out-of-vocab
        arrays={"tokens": np.asarray([V_INF], np.int32)}),
], ids=["no-uid", "uid-str", "uid-bool", "seed-str", "no-tokens",
        "tokens-2d", "tokens-float", "tokens-empty", "oversized",
        "oov"])
def test_fuzz_infer_malformed_rejected_service_lives(infer_server,
                                                     frame_fn):
    """Malformed-but-well-framed INFER: clean ERROR + close, then a valid
    request on a fresh connection still serves the bit-exact result."""
    srv, snap, scfg = infer_server
    from repro.serve.engine import result_checksum
    sock = socket.create_connection(srv.address, timeout=SOCK_TIMEOUT)
    sock.settimeout(SOCK_TIMEOUT)
    try:
        sock.sendall(frame_fn())
        _expect_error_then_close(sock)
    finally:
        sock.close()
    res = _infer_roundtrip(srv)
    ref = _reference_result(snap, scfg)
    assert result_checksum(res) == result_checksum(ref)


def test_fuzz_infer_mid_payload_disconnect(infer_server):
    """A client that vanishes mid-INFER is a protocol error on that
    connection only; the batcher keeps serving everyone else."""
    srv, snap, scfg = infer_server
    from repro.serve.engine import result_checksum
    before = srv.stats()["protocol_errors"]
    sock = socket.create_connection(srv.address, timeout=SOCK_TIMEOUT)
    sock.settimeout(SOCK_TIMEOUT)
    try:
        full = _infer_frame()
        sock.sendall(full[:protocol.HEADER_SIZE + 10])  # then vanish
    finally:
        sock.close()
    res = _infer_roundtrip(srv, uid=9, seed=5)
    ref = _reference_result(snap, scfg, uid=9, seed=5)
    assert result_checksum(res) == result_checksum(ref)
    assert srv.stats()["protocol_errors"] >= before + 1


def test_fuzz_infer_garbage_header_service_lives(infer_server):
    """The generic malformed-header abuse, against the inference port."""
    srv, snap, scfg = infer_server
    sock = socket.create_connection(srv.address, timeout=SOCK_TIMEOUT)
    sock.settimeout(SOCK_TIMEOUT)
    try:
        sock.sendall(b"EVIL" + protocol.pack_frame(
            MsgType.INFER, {"uid": 1, "seed": 0},
            {"tokens": _good_doc()})[4:])
        _expect_error_then_close(sock)
    finally:
        sock.close()
    assert _infer_roundtrip(srv, uid=11).n_sweeps == 2


def test_infer_wrong_type_rejected(infer_server):
    """A shard-protocol frame (PULL) at the inference server: semantic
    ERROR, connection closed, service lives."""
    srv, _, _ = infer_server
    sock = socket.create_connection(srv.address, timeout=SOCK_TIMEOUT)
    sock.settimeout(SOCK_TIMEOUT)
    try:
        sock.sendall(protocol.pack_frame(MsgType.PULL, {"round": 0}))
        _expect_error_then_close(sock)
    finally:
        sock.close()
    assert _infer_roundtrip(srv, uid=13).n_sweeps == 2
