"""Serving engine tests: slot lifecycle, batched decode, throughput path."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.models import model as model_lib
from repro.serve.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(ARCHITECTURES["qwen2-1.5b"])
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def make_reqs(cfg, n, prompt_len=8, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_engine_completes_all_requests(small_lm):
    cfg, params = small_lm
    engine = Engine(cfg, params, EngineConfig(batch=4, max_len=32))
    reqs = make_reqs(cfg, 6)
    done = engine.run(reqs)
    assert len(done) == 6
    for r in done:
        assert r.done
        assert len(r.output) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_engine_greedy_matches_manual_decode(small_lm):
    """One slot, greedy: the engine must reproduce a hand-rolled
    prefill + argmax decode loop exactly."""
    cfg, params = small_lm
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)

    engine = Engine(cfg, params, EngineConfig(batch=1, max_len=32))
    [req] = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])

    import jax.numpy as jnp
    logits, cache = model_lib.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, 32)
    manual = [int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))]
    tok = jnp.asarray([[manual[-1]]], jnp.int32)
    for _ in range(4):
        logits, cache = model_lib.decode_step(cfg, params, cache, tok)
        manual.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
        tok = jnp.asarray([[manual[-1]]], jnp.int32)
    assert req.output == manual


def test_engine_eos_stops_early(small_lm):
    cfg, params = small_lm
    engine = Engine(cfg, params, EngineConfig(batch=2, max_len=32, eos_id=0))
    reqs = make_reqs(cfg, 2, max_new=20)
    done = engine.run(reqs)
    for r in done:
        # stopped at eos or at the cap
        assert len(r.output) <= 20
        if len(r.output) < 20:
            assert r.output[-1] == 0


def test_engine_pool_independence(small_lm):
    """A request's tokens must not depend on which other requests share the
    pool (dead slots are masked)."""
    cfg, params = small_lm
    solo = Engine(cfg, params, EngineConfig(batch=4, max_len=32))
    [r_solo] = solo.run(make_reqs(cfg, 1, seed=7))
    pooled = Engine(cfg, params, EngineConfig(batch=4, max_len=32))
    rs = make_reqs(cfg, 4, seed=7)
    done = pooled.run(rs)
    r_pool = next(r for r in done if r.uid == 0)
    assert r_solo.output == r_pool.output
