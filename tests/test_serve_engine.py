"""Fold-in serving engine tests (DESIGN.md §14): slot lifecycle,
continuous batching, the bit-exact determinism contract vs the training
code path, and batch-composition independence."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import family as fam_mod
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.serve import (FoldInEngine, InferRequest, ServeConfig,
                         fold_in_perplexity, freeze, reference_fold_in,
                         result_checksum)
from repro.serve.engine import InferResult

MAX_LEN = 32
FAMILIES = ("lda", "pdp", "hdp")


@pytest.fixture(scope="module", params=FAMILIES)
def snapshot(request):
    """A lightly-trained frozen snapshot per family: a few in-process
    sweeps over a tiny corpus, then freeze(cfg, shared)."""
    fam = fam_mod.get(request.param)
    cfg = fam.config_cls(n_topics=4, vocab_size=64)
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=4, vocab_size=64, n_docs=24, doc_len=16, seed=1))
    local, shared = fam.init_state(cfg, tokens, mask,
                                   jax.random.PRNGKey(0))
    for i in range(3):
        tables, stale = fam.build_alias(cfg, shared)
        local, deltas = fam.sweep(cfg, local, shared, tables, stale,
                                  tokens, mask,
                                  jax.random.fold_in(
                                      jax.random.PRNGKey(9), i),
                                  method="mhw")
        shared = fam.apply_delta(shared, deltas)
        shared = fam.project(shared)
    return freeze(cfg, shared)


def make_reqs(snap, n, seed=0, min_len=3, max_len=MAX_LEN):
    rng = np.random.default_rng(seed)
    return [InferRequest(
        uid=i,
        tokens=rng.integers(0, snap.vocab_size,
                            size=int(rng.integers(min_len, max_len + 1))
                            ).astype(np.int32),
        seed=100 + i) for i in range(n)]


def scfg(**kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("n_sweeps", 3)
    return ServeConfig(**kw)


# ---------------------------------------------------------------------------
# Lifecycle
# ---------------------------------------------------------------------------

def test_engine_completes_all_requests(snapshot):
    """More requests than slots: continuous batching must serve all of
    them with well-formed results."""
    eng = FoldInEngine(snapshot, scfg())
    reqs = make_reqs(snapshot, 7)
    results = eng.run(reqs)
    assert sorted(results) == list(range(7))
    k = snapshot.n_topics
    for req in reqs:
        res = results[req.uid]
        assert res.n_sweeps == 3
        assert res.theta.shape == (k,)
        assert np.isclose(res.theta.sum(), 1.0, atol=1e-4)
        assert res.assignments.shape == (len(req.tokens),)
        assert ((res.assignments >= 0)
                & (res.assignments
                   < snapshot.family.n_outcomes(snapshot.cfg))).all()
    assert eng.docs_admitted == eng.docs_harvested == 7
    assert eng.free_slots() == 4


def test_admit_step_harvest_cycle(snapshot):
    eng = FoldInEngine(snapshot, scfg(max_slots=2, n_sweeps=2))
    reqs = make_reqs(snapshot, 3)
    assert eng.admit(reqs[0])
    assert eng.admit(reqs[1])
    assert not eng.admit(reqs[2])          # grid full → False, not an error
    assert eng.free_slots() == 0
    assert eng.harvest() == []             # nothing mixed yet
    eng.step()
    assert eng.harvest() == []             # age 1 < n_sweeps 2
    eng.step()
    done = eng.harvest()
    assert sorted(r.uid for r in done) == [0, 1]
    assert eng.free_slots() == 2           # slots recycled
    assert eng.admit(reqs[2])


def test_admit_validation(snapshot):
    eng = FoldInEngine(snapshot, scfg())
    with pytest.raises(ValueError, match="empty"):
        eng.admit(InferRequest(uid=0, tokens=np.zeros(0, np.int32)))
    with pytest.raises(ValueError, match="max_len"):
        eng.admit(InferRequest(
            uid=1, tokens=np.zeros(MAX_LEN + 1, np.int32)))
    with pytest.raises(ValueError, match="vocab"):
        eng.admit(InferRequest(
            uid=2, tokens=np.asarray([snapshot.vocab_size], np.int32)))
    # nothing was admitted by the failed attempts
    assert eng.free_slots() == 4


# ---------------------------------------------------------------------------
# The §14 determinism contract
# ---------------------------------------------------------------------------

def test_fold_in_bit_identical_to_trainer_path(snapshot):
    """Acceptance: a document folded in through the batched engine is
    bit-identical — assignments AND theta — to the same document swept
    through the training path (``family.sweep``, layout="sorted") with
    pushes disabled."""
    eng = FoldInEngine(snapshot, scfg())
    reqs = make_reqs(snapshot, 5, seed=11)
    results = eng.run(reqs)
    for req in reqs:
        _, theta, z = reference_fold_in(
            snapshot, req.tokens, req.seed, n_sweeps=3, max_len=MAX_LEN)
        res = results[req.uid]
        np.testing.assert_array_equal(res.assignments, z)
        np.testing.assert_array_equal(res.theta, theta)
        ref = InferResult(uid=req.uid, theta=theta, assignments=z,
                          n_sweeps=3)
        assert result_checksum(ref) == result_checksum(res)


def test_batch_composition_independence(snapshot):
    """The same (tokens, seed) request gives bit-identical results alone,
    with batch-mates, and under a different admission order — the chain
    is a pure function of (snapshot, tokens, seed)."""
    reqs = make_reqs(snapshot, 4, seed=23)

    solo = FoldInEngine(snapshot, scfg()).run([reqs[0]])
    pooled = FoldInEngine(snapshot, scfg()).run(reqs)
    reordered = FoldInEngine(snapshot, scfg(max_slots=2)).run(
        list(reversed(reqs)))

    for res_set in (pooled, reordered):
        np.testing.assert_array_equal(solo[0].assignments,
                                      res_set[0].assignments)
        np.testing.assert_array_equal(solo[0].theta, res_set[0].theta)
    for uid in range(4):
        assert (result_checksum(pooled[uid])
                == result_checksum(reordered[uid]))


def test_seed_changes_chain(snapshot):
    """Different request seeds must decorrelate the chains (the uniforms
    really are drawn per request, not per batch)."""
    toks = make_reqs(snapshot, 1, seed=5, min_len=MAX_LEN)[0].tokens
    a = FoldInEngine(snapshot, scfg()).run(
        [InferRequest(uid=0, tokens=toks, seed=1)])[0]
    b = FoldInEngine(snapshot, scfg()).run(
        [InferRequest(uid=0, tokens=toks, seed=2)])[0]
    assert not np.array_equal(a.assignments, b.assignments)


# ---------------------------------------------------------------------------
# Quality plumbing
# ---------------------------------------------------------------------------

def test_fold_in_perplexity_finite(snapshot):
    n, length = 4, 12
    rng = np.random.default_rng(2)
    tokens = rng.integers(0, snapshot.vocab_size, (n, length)
                          ).astype(np.int32)
    mask = np.ones((n, length), bool)
    eng = FoldInEngine(snapshot, scfg())
    results = eng.run([InferRequest(uid=i, tokens=tokens[i], seed=i)
                       for i in range(n)])
    thetas = np.stack([results[i].theta for i in range(n)])
    ppl = fold_in_perplexity(snapshot, thetas, tokens, mask)
    # uniform-random tokens score worse than the vocab size on a peaked
    # model — only finiteness and a loose ceiling are meaningful here
    assert np.isfinite(ppl) and 1.0 < ppl < snapshot.vocab_size ** 2
