"""Data pipeline + optimizer tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.data.synthetic import (CorpusConfig, lm_batches, make_topic_corpus,
                                  shard_corpus)
from repro.optim import adamw


class TestCorpus:
    def test_shapes_and_mask(self):
        cfg = CorpusConfig(n_topics=4, vocab_size=64, n_docs=16, doc_len=24)
        tokens, mask, phi = make_topic_corpus(cfg)
        assert tokens.shape == (16, 24)
        assert mask.shape == (16, 24)
        assert phi.shape == (4, 64)
        assert tokens.min() >= 0 and tokens.max() < 64
        # masked positions are contiguous prefixes
        for d in range(16):
            lens = mask[d].sum()
            assert mask[d, :lens].all() and not mask[d, lens:].any()

    def test_power_law_marginals(self):
        """Word frequencies must be heavy-tailed (the PDP's motivation)."""
        cfg = CorpusConfig(n_topics=4, vocab_size=256, n_docs=256,
                           doc_len=64, zipf_a=1.2)
        tokens, mask, _ = make_topic_corpus(cfg)
        counts = np.bincount(tokens[mask], minlength=256)
        counts = np.sort(counts)[::-1].astype(float)
        top10 = counts[:10].sum() / counts.sum()
        assert top10 > 0.25, f"not heavy-tailed: top-10 share {top10:.3f}"

    def test_sharding_partition(self):
        cfg = CorpusConfig(n_topics=4, vocab_size=64, n_docs=16, doc_len=8)
        tokens, mask, _ = make_topic_corpus(cfg)
        shards = shard_corpus(tokens, mask, 4)
        assert len(shards) == 4
        rebuilt = np.concatenate([t for t, _ in shards])
        np.testing.assert_array_equal(rebuilt, tokens[:16])

    def test_lm_batches_learnable_stream(self):
        batches = list(lm_batches(64, 4, 16, 3, kind="affine", noise=0.0))
        assert len(batches) == 3
        t = batches[0]["tokens"]
        # noise=0: exact affine recurrence
        np.testing.assert_array_equal(t[:, 1:], (t[:, :-1] * 3 + 1) % 64)


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = adamw.update(params, grads, state, lr=5e-2,
                                         weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_weight_decay_only_on_matrices(self):
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = adamw.init(params)
        grads = jax.tree.map(jnp.zeros_like, params)
        p2, _ = adamw.update(params, grads, state, lr=0.1, weight_decay=0.5)
        assert float(p2["w"].max()) < 1.0          # decayed
        np.testing.assert_array_equal(np.asarray(p2["b"]), 1.0)  # not decayed

    @given(st.floats(1e-5, 1e-2), st.integers(1, 50), st.integers(60, 200))
    @settings(max_examples=20, deadline=None)
    def test_schedule_bounds(self, peak, warmup, total):
        for s in [0, warmup, (warmup + total) // 2, total, total + 10]:
            lr = float(adamw.cosine_schedule(jnp.asarray(s), peak_lr=peak,
                                             warmup=warmup, total=total))
            assert 0.0 <= lr <= peak * (1 + 1e-6)
        # end of schedule: min_ratio * peak
        end = float(adamw.cosine_schedule(jnp.asarray(total), peak_lr=peak,
                                          warmup=warmup, total=total))
        assert end == pytest.approx(0.1 * peak, rel=1e-3)

    def test_grad_clip_engages(self):
        params = {"w": jnp.zeros((4,))}
        state = adamw.init(params)
        huge = {"w": jnp.full((4,), 1e6)}
        p_clip, _ = adamw.update(params, huge, state, lr=1.0, grad_clip=1.0,
                                 weight_decay=0.0)
        # post-clip step is bounded by lr·(1/sqrt(v̂)·m̂) ≈ lr
        assert float(jnp.abs(p_clip["w"]).max()) <= 1.0 + 1e-5
