"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alias as alias_mod
from repro.kernels import alias_build, alias_sample, mh_accept, ops, ref
from tests.test_alias import implied_distribution


@pytest.mark.parametrize("v,k,tile_r", [
    (16, 8, 8), (64, 32, 8), (32, 128, 4), (64, 250, 16), (8, 16, 8),
])
def test_alias_build_kernel_vs_ref(v, k, tile_r):
    p = jax.random.gamma(jax.random.PRNGKey(v * k), 0.3, (v, k)) + 1e-4
    prob_k, alias_k, mass_k = alias_build.alias_build(p, tile_r=tile_r)
    prob_r, alias_r, mass_r = ref.alias_build_ref(p)
    np.testing.assert_allclose(np.asarray(mass_k), np.asarray(mass_r), rtol=1e-6)
    # Tables may differ structurally (stack processing order), so compare the
    # *distributions they encode* — the semantic contract.
    tk = alias_mod.AliasTable(prob_k, alias_k, mass_k)
    tr = alias_mod.AliasTable(prob_r, alias_r, mass_r)
    target = np.asarray(p / p.sum(-1, keepdims=True))
    np.testing.assert_allclose(implied_distribution(tk), target, atol=2e-5)
    np.testing.assert_allclose(implied_distribution(tr), target, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_alias_build_fused_kernel(dtype):
    v, k = 32, 16
    n_wk = (jax.random.gamma(jax.random.PRNGKey(0), 1.0, (v, k)) * 10).astype(dtype)
    n_k = n_wk.sum(0)
    tabs, stale = ops.build_tables_fused_lda(
        n_wk.astype(jnp.float32), n_k.astype(jnp.float32),
        alpha=0.1, beta=0.01, vocab_size=v)
    dp = ref.dense_probs_ref(n_wk.astype(jnp.float32), n_k.astype(jnp.float32),
                             0.1, 0.01, v)
    target = np.asarray(dp / dp.sum(-1, keepdims=True))
    np.testing.assert_allclose(implied_distribution(tabs), target, atol=2e-5)
    np.testing.assert_allclose(np.asarray(tabs.mass), np.asarray(dp.sum(-1)),
                               rtol=1e-5)


@pytest.mark.parametrize("v,k,b,tile_v,tile_b", [
    (64, 32, 2048, 16, 256),
    (16, 8, 128, 16, 128),
    (128, 64, 512, 32, 512),
    (64, 250, 1024, 8, 64),
])
def test_alias_sample_kernel_exact(v, k, b, tile_v, tile_b):
    """Given identical uniforms the kernel must match the oracle exactly."""
    key = jax.random.PRNGKey(b)
    p = jax.random.gamma(key, 0.3, (v, k)) + 1e-4
    prob, al, _ = alias_build.alias_build(p, tile_r=min(8, v))
    rows = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, v, jnp.int32)
    slot = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, k, jnp.int32)
    coin = jax.random.uniform(jax.random.fold_in(key, 3), (b,))
    out_k = alias_sample.alias_sample(prob, al, rows, slot, coin,
                                      tile_v=tile_v, tile_b=tile_b)
    out_r = ref.alias_sample_ref(prob, al, rows, slot, coin)
    assert bool(jnp.all(out_k == out_r))


@pytest.mark.parametrize("b,tile_b", [(4096, 512), (128, 128), (1024, 256)])
def test_mh_accept_kernel_exact(b, tile_b):
    key = jax.random.PRNGKey(b)
    k = 32
    z = jax.random.randint(jax.random.fold_in(key, 0), (b,), 0, k, jnp.int32)
    cand = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, k, jnp.int32)
    lps = [jax.random.normal(jax.random.fold_in(key, i), (b,)) for i in range(2, 6)]
    u = jax.random.uniform(jax.random.fold_in(key, 6), (b,))
    out_k = mh_accept.mh_accept(z, cand, *lps, u, tile_b=tile_b)
    out_r = ref.mh_accept_ref(z, cand, *lps, u)
    assert bool(jnp.all(out_k == out_r))


def test_ops_sample_rows_statistics():
    """End-to-end kernel path draws match the target distribution."""
    key = jax.random.PRNGKey(0)
    v, k = 16, 32
    p = jax.random.gamma(key, 0.5, (v, k)) + 1e-3
    tables = ops.build_tables(p, tile_r=8)
    rows = jnp.repeat(jnp.arange(v), 4000)
    s = np.asarray(ops.sample_rows(tables, rows, jax.random.PRNGKey(1),
                                   tile_v=8, tile_b=4000)).reshape(v, -1)
    for r in range(0, v, 5):
        emp = np.bincount(s[r], minlength=k) / s.shape[1]
        refd = np.asarray(p[r] / p[r].sum())
        assert 0.5 * np.abs(emp - refd).sum() < 0.05


@pytest.mark.parametrize("tile_k", [4, 8, 16])
def test_alias_build_tile_k_bitexact(tile_k):
    """The 2-phase K-tiled alias build (stage → build-on-scratch → flush)
    equals the untiled single-phase kernel bit for bit."""
    v, k = 32, 16
    p = jax.random.gamma(jax.random.PRNGKey(7), 0.5, (v, k)) + 1e-4
    want = alias_build.alias_build(p, tile_r=8)
    got = alias_build.alias_build(p, tile_r=8, tile_k=tile_k)
    for a, b in zip(want, got):
        assert bool(jnp.all(a == b))


@pytest.mark.parametrize("tile_k", [4, 8, 16])
def test_alias_build_fused_tile_k_bitexact(tile_k):
    """The fused LDA-term build stages *raw* n_wk/n_k tiles and computes
    the dense term on full-K scratch — so XLA cannot round the
    elementwise term differently per block shape (the 1-ulp trap)."""
    v, k = 32, 16
    key = jax.random.PRNGKey(11)
    n_wk = jnp.floor(jax.random.gamma(key, 1.0, (v, k)) * 4)
    n_k = n_wk.sum(0)
    kw = dict(alpha=0.1, beta=0.01, vocab_size=v, tile_r=8)
    want = alias_build.alias_build_fused(n_wk, n_k, **kw)
    got = alias_build.alias_build_fused(n_wk, n_k, tile_k=tile_k, **kw)
    for a, b in zip(want, got):
        assert bool(jnp.all(a == b))
